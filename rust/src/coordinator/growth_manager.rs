//! The LiGO growth manager — the paper's §3.2/3.3 pipeline at runtime,
//! behind the **one** public entry point
//! [`Ligo::grow(ctx)`](crate::growth::ligo::Ligo):
//!
//! 1. initialize M with the stacking + neuron-duplication pattern
//!    (Prop. 1: LiGO's family contains StackBERT/Net2Net, so this start
//!    point *is* the best non-learned baseline);
//! 2. run N (default 100) SGD-momentum steps on M;
//! 3. materialize Theta_large = M(Theta_small);
//! 4. account the extra FLOPs (Table 3) and hand the params to the trainer.
//!
//! Route selection happens **exactly once**, in the crate-internal
//! `ligo_route`, from what the [`GrowthContext`] provides — callers never
//! pick a route by hand,
//! and every considered route is logged in [`GrowthOutcome::route`]:
//!
//! * **task-artifact** — context carries a runtime handle *and* a batch
//!   source, and the `ligo_grad_{s}__{t}` / `ligo_apply_{s}__{t}` artifacts
//!   compile (the `pjrt`-feature fast path): M trains against the expanded
//!   model's *task loss* inside one fused XLA graph.
//! * **task-native** — a batch source but no usable artifacts: each M-step
//!   expands `Theta_large = M(Theta_small)`
//!   ([`crate::growth::ligo::ligo_apply`]), runs the native engine's
//!   forward/backward ([`crate::model::loss_and_grads`]) on a real
//!   pretraining batch, and chains dL/dTheta_large through the fused
//!   `B W A^T` width pass and the depth blends
//!   ([`crate::growth::ligo::ligo_apply_backward`]) — the same objective as
//!   the artifact path, no XLA required.
//! * **surrogate** — no task batches (or an unsupported family): the
//!   least-squares fit of [`crate::growth::ligo::Ligo::grow_with_loss`].
//!
//! Errors *inside* the chosen M-training loop are real failures and
//! propagate — they must not silently switch the training objective.
//! The legacy `ligo_grow_*` functions are crate-internal route
//! implementations now; unit tests below pin each one bit-for-bit to its
//! context configuration.

use std::sync::Arc;

use crate::config::ModelConfig;
use crate::coordinator::flops;
use crate::coordinator::optim::Sgd;
use crate::error::Result;
use crate::growth::{GrowthContext, GrowthMetrics, GrowthOutcome, Objective};
use crate::log_info;
use crate::runtime::Executable;
use crate::tensor::{store::Store, Tensor};
use crate::util::rng::Rng;

pub use crate::growth::context::LigoOptions;

/// Initialize the LiGO parameter store M from manifest shapes: width
/// matrices get the cyclic duplication pattern, depth matrices the stacking
/// pattern (both + symmetry-breaking noise) — mirrors python ligo_init.
pub fn ligo_init_store(shapes: &[(String, Vec<usize>)], noise: f32, seed: u64) -> Store {
    let mut rng = Rng::new(seed ^ 0x11C0);
    let mut store = Store::new();
    for (name, shape) in shapes {
        assert_eq!(shape.len(), 2, "LiGO params are matrices: {name}");
        let (rows, cols) = (shape[0], shape[1]);
        let mut data = vec![0.0f32; rows * cols];
        for r in 0..rows {
            data[r * cols + (r % cols)] = 1.0;
        }
        for v in data.iter_mut() {
            *v += noise * rng.normal();
        }
        store.insert(name.clone(), Tensor::from_f32(shape, data));
    }
    store
}

/// The single route-selection point behind `Ligo::grow(ctx)`: negotiate
/// artifact vs. native task loss vs. surrogate from what the context
/// provides, try each eligible route in preference order, and record every
/// decision in the outcome's route log. M-learning options come from the
/// context when set, else from the operator's own fields — explicitly-
/// configured operators are never silently overridden by defaults.
pub(crate) fn ligo_route(
    op: &crate::growth::ligo::Ligo,
    ctx: GrowthContext<'_, '_>,
) -> Result<GrowthOutcome> {
    let GrowthContext { small, small_cfg, large_cfg, runtime, mut batches, opts, seed } = ctx;
    let mut opts = opts.unwrap_or_else(|| op.options());
    if let Some(s) = seed {
        opts.seed = s;
    }
    let mut route: Vec<String> = Vec::new();

    // ---- 1. artifact fast path (runtime handle + batch source) ----
    if batches.is_none() && runtime.is_none() {
        route.push("task-artifact: skipped (no runtime handle, no batch source)".into());
    } else if batches.is_none() {
        route.push("task-artifact: skipped (no batch source)".into());
    } else if let Some(rt) = runtime {
        let pair = format!("{}__{}", small_cfg.name, large_cfg.name);
        let loaded = rt
            .load(&format!("ligo_grad_{pair}"))
            .and_then(|grad| rt.load(&format!("ligo_apply_{pair}")).map(|apply| (grad, apply)));
        match loaded {
            Ok((grad, apply)) => {
                route.push("task-artifact: selected (artifacts compiled)".into());
                let b = batches.as_mut().expect("batch source checked above");
                let mut out = ligo_train_artifact(
                    &grad, &apply, small_cfg, large_cfg, small, &mut **b, &opts,
                )?;
                out.route = route;
                return Ok(out);
            }
            Err(e) => {
                log_info!(
                    "LiGO artifacts unavailable for {}->{} ({e}); using the native engine",
                    small_cfg.name,
                    large_cfg.name
                );
                route.push(format!("task-artifact: unavailable ({e})"));
            }
        }
    } else {
        route.push("task-artifact: skipped (no runtime handle)".into());
    }

    // ---- 2. native task loss (batch source + supported family) ----
    if let Some(b) = batches.as_mut() {
        if !crate::model::supports(large_cfg) {
            route.push(format!(
                "task-native: skipped (family '{}' unsupported by the native engine)",
                large_cfg.family
            ));
        } else if !usable_task_batch(large_cfg, &(**b)(0)) {
            route.push("task-native: skipped (batch 0 lacks the task keys)".into());
        } else {
            route.push("task-native: selected (native engine)".into());
            let mut out = ligo_grow_task_native(small_cfg, large_cfg, small, &mut **b, &opts)?;
            out.route = route;
            return Ok(out);
        }
    } else {
        route.push("task-native: skipped (no batch source)".into());
    }

    // ---- 3. surrogate fallback (always possible) ----
    // the *reason* no better route ran is already in the log above (no
    // batch source / missing task keys / unsupported family) — don't
    // restate a possibly-wrong one here
    log_info!(
        "{} -> {}: training M on the surrogate objective [{}]",
        small_cfg.name,
        large_cfg.name,
        route.join(" -> ")
    );
    route.push("surrogate: selected (fallback)".into());
    let mut out = ligo_grow_surrogate(small_cfg, large_cfg, small, &opts)?;
    out.route = route;
    Ok(out)
}

/// The M-training loop over loaded artifacts (the task-artifact route).
#[allow(clippy::too_many_arguments)]
fn ligo_train_artifact(
    grad: &Arc<Executable>,
    apply: &Arc<Executable>,
    small: &ModelConfig,
    large: &ModelConfig,
    small_params: &Store,
    batches: &mut dyn FnMut(usize) -> Store,
    opts: &LigoOptions,
) -> Result<GrowthOutcome> {
    let timer = crate::util::timer::Timer::new();
    let mut m = ligo_init_store(&grad.manifest.shapes_of("ligo"), opts.init_noise, opts.seed);
    let mut sgd = Sgd::new(&m, opts.momentum);
    let mut last_loss = f32::NAN;
    for step in 0..opts.steps {
        let batch = batches(step);
        let out = grad.run(&[("ligo", &m), ("small", small_params), ("batch", &batch)])?;
        last_loss = out.scalar("loss").unwrap_or(f32::NAN);
        let grads = out.groups.get("grads").expect("ligo grads");
        // cosine-ish decay over the short M-learning phase (shared schedule)
        let lr = crate::growth::ligo::m_lr_at(opts.lr, step, opts.steps);
        sgd.step(&mut m, grads, lr);
        if step % 25 == 0 {
            log_info!("ligo M-step {step}: loss {last_loss:.4}");
        }
    }
    let out = apply.run(&[("ligo", &m), ("small", small_params)])?;
    let params = out
        .groups
        .get("out")
        .expect("ligo_apply returns params")
        .clone();
    let extra_flops = opts.steps as f64 * flops::ligo_step_flops(small, large)
        + flops::ligo_apply_flops(small, large);
    Ok(GrowthOutcome {
        params,
        objective: Objective::TaskArtifact,
        metrics: GrowthMetrics {
            extra_flops,
            wall_s: timer.elapsed(),
            final_m_loss: last_loss,
            m_steps: opts.steps,
        },
        route: Vec::new(),
    })
}

/// Does this batch carry the keys the native engine needs for `cfg`?
fn usable_task_batch(cfg: &ModelConfig, batch: &Store) -> bool {
    if cfg.is_vision() {
        batch.contains("images") && batch.contains("labels")
    } else {
        batch.contains("tokens") && batch.contains("labels")
    }
}

/// True task-loss M-learning without XLA (paper Algorithm 1): per step,
/// materialize `Theta_large = M(Theta_small)`, run the native engine's
/// forward/backward on a pretraining batch, chain dL/dTheta_large through
/// the expansion (`ligo_apply_backward`) into dL/dM, and take an
/// SGD-momentum step on M. Crate-internal: reach it through
/// `Ligo::grow(ctx)` with a batch source.
pub(crate) fn ligo_grow_task_native(
    small: &ModelConfig,
    large: &ModelConfig,
    small_params: &Store,
    batches: &mut dyn FnMut(usize) -> Store,
    opts: &LigoOptions,
) -> Result<GrowthOutcome> {
    use crate::growth::ligo::{ligo_apply, ligo_apply_backward, ligo_init, m_lr_at};
    let timer = crate::util::timer::Timer::new();
    let mut m = ligo_init(small, large, opts.init_noise, opts.seed);
    let mut sgd = Sgd::new(&m, opts.momentum);
    let mut last_loss = f32::NAN;
    for step in 0..opts.steps {
        let batch = batches(step);
        let theta = ligo_apply(&m, small_params, small, large);
        let (loss, grads_theta, _metric) = crate::model::loss_and_grads(large, &theta, &batch)?;
        last_loss = loss;
        let dm = ligo_apply_backward(&m, small_params, &grads_theta, small, large);
        // the expanded model and its gradients die here every step —
        // recycle their (large-model-sized) buffers for the next iteration
        crate::tensor::arena::recycle_store(theta);
        crate::tensor::arena::recycle_store(grads_theta);
        // cosine-ish decay over the short M-learning phase (shared schedule)
        let lr = m_lr_at(opts.lr, step, opts.steps);
        sgd.step(&mut m, &dm, lr);
        crate::tensor::arena::recycle_store(dm);
        if step % 25 == 0 {
            log_info!("ligo M-step {step} (native task loss): loss {last_loss:.4}");
        }
    }
    let params = ligo_apply(&m, small_params, small, large);
    if opts.steps == 0 {
        last_loss = crate::model::loss_only(large, &params, &batches(0))?.0;
    }
    let extra_flops = opts.steps as f64 * flops::ligo_step_flops(small, large)
        + flops::ligo_apply_flops(small, large);
    Ok(GrowthOutcome {
        params,
        objective: Objective::TaskNative,
        metrics: GrowthMetrics {
            extra_flops,
            wall_s: timer.elapsed(),
            final_m_loss: last_loss,
            m_steps: opts.steps,
        },
        route: Vec::new(),
    })
}

/// The surrogate fallback: the [`crate::growth::ligo::Ligo`] operator
/// (least-squares M-learning against the StackBERT+Interpolation ensemble),
/// with FLOPs accounted analytically — M-steps backprop only through the
/// expansion, not a large-model fwd/bwd, hence the cheaper per-step cost.
/// Crate-internal: reach it through `Ligo::grow(ctx)` with a param-only
/// context.
pub(crate) fn ligo_grow_surrogate(
    small: &ModelConfig,
    large: &ModelConfig,
    small_params: &Store,
    opts: &LigoOptions,
) -> Result<GrowthOutcome> {
    let timer = crate::util::timer::Timer::new();
    let op = crate::growth::ligo::Ligo {
        steps: opts.steps,
        lr: opts.lr,
        momentum: opts.momentum,
        noise: opts.init_noise,
        seed: opts.seed,
    };
    let (params, final_m_loss) = op.grow_with_loss(small_params, small, large);
    let extra_flops = opts.steps as f64 * flops::ligo_native_step_flops(small, large)
        + flops::ligo_apply_flops(small, large);
    Ok(GrowthOutcome {
        params,
        objective: Objective::Surrogate,
        metrics: GrowthMetrics {
            extra_flops,
            wall_s: timer.elapsed(),
            final_m_loss,
            m_steps: opts.steps,
        },
        route: Vec::new(),
    })
}

/// Depth-only / width-only variants (Fig. 6) use the same entry point with
/// the ablation pairs (bert_d3w72 -> bert_base, bert_d6w48 -> bert_base);
/// M simply lacks the other direction's parameters.

#[cfg(test)]
mod tests {
    use super::*;
    use crate::growth::by_name;
    use crate::growth::testutil::{assert_store_eq, mk_cfg, small_store};
    use crate::runtime::Runtime;

    #[test]
    fn init_pattern_is_stack_plus_noise() {
        let shapes = vec![
            ("w_q".to_string(), vec![6, 3]),
            ("B_emb".to_string(), vec![12, 8]),
        ];
        let m = ligo_init_store(&shapes, 0.0, 0);
        let w = m.expect("w_q");
        // rows 0..3 identity, rows 3..6 repeat (stacking pattern)
        for r in 0..6 {
            for c in 0..3 {
                let want = if c == r % 3 { 1.0 } else { 0.0 };
                assert_eq!(w.at2(r, c), want, "r{r} c{c}");
            }
        }
        let b = m.expect("B_emb");
        assert_eq!(b.at2(9, 1), 1.0); // 9 % 8 = 1
    }

    #[test]
    fn noise_breaks_symmetry_deterministically() {
        let shapes = vec![("B_emb".to_string(), vec![4, 2])];
        let a = ligo_init_store(&shapes, 0.01, 7);
        let b = ligo_init_store(&shapes, 0.01, 7);
        let c = ligo_init_store(&shapes, 0.01, 8);
        assert_eq!(a.expect("B_emb"), b.expect("B_emb"));
        assert_ne!(a.expect("B_emb"), c.expect("B_emb"));
    }

    #[test]
    fn default_options_match_paper() {
        assert_eq!(LigoOptions::default().steps, 100);
    }

    fn mk_batch(cfg: &ModelConfig, seed: u64) -> Store {
        let mut rng = crate::util::rng::Rng::new(seed);
        let (b, s) = (cfg.batch, cfg.seq);
        let tokens: Vec<i32> = (0..b * s).map(|_| rng.below(cfg.vocab) as i32).collect();
        let labels: Vec<i32> = tokens
            .iter()
            .map(|&t| if rng.coin(0.3) { t } else { -1 })
            .collect();
        let mut st = Store::new();
        st.insert("tokens", Tensor::from_i32(&[b, s], tokens));
        st.insert("labels", Tensor::from_i32(&[b, s], labels));
        st
    }

    #[test]
    fn context_with_batches_routes_to_the_task_loss_and_logs_the_chain() {
        let rt = Runtime::cpu(std::env::temp_dir().join("ligo_gm_no_artifacts")).unwrap();
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(4, 12, 3);
        let small = small_store(&cs);
        let opts = LigoOptions { steps: 5, ..Default::default() };
        let mut batches = |s: usize| mk_batch(&mk_cfg(4, 12, 3), 100 + s as u64);
        let ctx = GrowthContext::new(&small, &cs, &cl)
            .with_runtime(&rt)
            .with_batches(&mut batches)
            .with_opts(opts);
        let grown = by_name("ligo").unwrap().grow(ctx).unwrap();
        assert_eq!(grown.objective, Objective::TaskNative);
        assert!(grown.metrics.final_m_loss.is_finite());
        assert!(grown.metrics.extra_flops > 0.0);
        assert_eq!(grown.metrics.m_steps, 5);
        assert_eq!(grown.params.len(), small_store(&cl).len());
        assert_eq!(grown.params.expect("L03_q_w").shape, vec![12, 12]);
        // the fallback chain names the artifact route it passed over
        assert!(
            grown.route[0].starts_with("task-artifact:"),
            "route log: {:?}",
            grown.route
        );
        assert!(
            grown.route.last().unwrap().contains("task-native: selected"),
            "route log: {:?}",
            grown.route
        );
    }

    #[test]
    fn task_native_route_is_reproduced_bit_for_bit_by_the_context() {
        // equivalence pin: the ctx configuration (batches, no runtime) must
        // reproduce the legacy ligo_grow_task_native route exactly
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(4, 12, 3);
        let small = small_store(&cs);
        let opts = LigoOptions { steps: 4, ..Default::default() };
        let mut b1 = |s: usize| mk_batch(&mk_cfg(4, 12, 3), 500 + s as u64);
        let legacy = ligo_grow_task_native(&cs, &cl, &small, &mut b1, &opts).unwrap();
        let mut b2 = |s: usize| mk_batch(&mk_cfg(4, 12, 3), 500 + s as u64);
        let ctx = GrowthContext::new(&small, &cs, &cl)
            .with_batches(&mut b2)
            .with_opts(opts);
        let unified = by_name("ligo").unwrap().grow(ctx).unwrap();
        assert_eq!(unified.objective, legacy.objective);
        assert_eq!(unified.metrics.final_m_loss, legacy.metrics.final_m_loss);
        assert_store_eq(&unified.params, &legacy.params, "task-native equivalence");
    }

    #[test]
    fn surrogate_route_is_reproduced_bit_for_bit_by_a_param_only_context() {
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(4, 12, 3);
        let small = small_store(&cs);
        let opts = LigoOptions { steps: 6, ..Default::default() };
        let legacy = ligo_grow_surrogate(&cs, &cl, &small, &opts).unwrap();
        let ctx = GrowthContext::new(&small, &cs, &cl).with_opts(opts);
        let unified = by_name("ligo").unwrap().grow(ctx).unwrap();
        assert_eq!(unified.objective, Objective::Surrogate);
        assert_eq!(unified.metrics.final_m_loss, legacy.metrics.final_m_loss);
        assert_store_eq(&unified.params, &legacy.params, "surrogate equivalence");
        assert!(
            unified.route.iter().any(|r| r.contains("surrogate: selected")),
            "route log: {:?}",
            unified.route
        );
    }

    #[test]
    fn operator_fields_are_honored_when_the_context_sets_no_options() {
        // `Ligo { steps: 3, .. }.grow(ctx)` without with_opts must run 3
        // M-steps, not a silently-overriding 100-step default
        use crate::growth::{ligo::Ligo, GrowthOperator};
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(4, 12, 3);
        let small = small_store(&cs);
        let op = Ligo { steps: 3, ..Default::default() };
        let grown = op.grow(GrowthContext::new(&small, &cs, &cl)).unwrap();
        assert_eq!(grown.metrics.m_steps, 3);
        assert_eq!(grown.objective, Objective::Surrogate);
        // ...and an explicit context still wins
        let ctx = GrowthContext::new(&small, &cs, &cl)
            .with_opts(LigoOptions { steps: 2, ..Default::default() });
        assert_eq!(op.grow(ctx).unwrap().metrics.m_steps, 2);
    }

    #[test]
    fn empty_batches_fall_back_to_the_surrogate_objective() {
        // batches that lack the task keys must demote to the surrogate —
        // with the skip reason in the route log, not silently
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(4, 12, 3);
        let small = small_store(&cs);
        let opts = LigoOptions { steps: 5, ..Default::default() };
        let mut batches = |_s: usize| Store::new();
        let ctx = GrowthContext::new(&small, &cs, &cl)
            .with_batches(&mut batches)
            .with_opts(opts);
        let grown = by_name("ligo").unwrap().grow(ctx).unwrap();
        assert_eq!(grown.objective, Objective::Surrogate);
        assert!(grown.metrics.final_m_loss.is_finite());
        assert!(
            grown.route.iter().any(|r| r.contains("task-native: skipped")),
            "route log: {:?}",
            grown.route
        );
    }

    #[test]
    fn task_native_m_learning_descends_the_task_loss() {
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(4, 12, 3);
        let small = small_store(&cs);
        // the same fixed batch each step: loss at step N must beat step 0
        let mut batches = |_s: usize| mk_batch(&mk_cfg(4, 12, 3), 7);
        let l0 = ligo_grow_task_native(
            &cs,
            &cl,
            &small,
            &mut batches,
            &LigoOptions { steps: 0, ..Default::default() },
        )
        .unwrap();
        let ln = ligo_grow_task_native(
            &cs,
            &cl,
            &small,
            &mut batches,
            &LigoOptions { steps: 20, ..Default::default() },
        )
        .unwrap();
        assert!(l0.metrics.final_m_loss.is_finite() && ln.metrics.final_m_loss.is_finite());
        assert!(
            ln.metrics.final_m_loss < l0.metrics.final_m_loss,
            "task-loss M-learning must descend: {} -> {}",
            l0.metrics.final_m_loss,
            ln.metrics.final_m_loss
        );
    }

    #[test]
    fn native_flops_accounting_scales_with_steps_and_objective() {
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(4, 12, 3);
        let small = small_store(&cs);
        let g5 =
            ligo_grow_surrogate(&cs, &cl, &small, &LigoOptions { steps: 5, ..Default::default() })
                .unwrap();
        let g9 =
            ligo_grow_surrogate(&cs, &cl, &small, &LigoOptions { steps: 9, ..Default::default() })
                .unwrap();
        assert!(g9.metrics.extra_flops > g5.metrics.extra_flops);
        assert_eq!(g5.objective, Objective::Surrogate);
        // a task-native step costs more FLOPs than a surrogate step (it
        // pays the large-model fwd/bwd on top of the expansion backprop)
        let mut batches = |_s: usize| mk_batch(&mk_cfg(4, 12, 3), 9);
        let t5 = ligo_grow_task_native(
            &cs,
            &cl,
            &small,
            &mut batches,
            &LigoOptions { steps: 5, ..Default::default() },
        )
        .unwrap();
        assert!(t5.metrics.extra_flops > g5.metrics.extra_flops);
    }
}
