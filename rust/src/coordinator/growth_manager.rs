//! The LiGO growth manager — the paper's §3.2/3.3 pipeline at runtime:
//!
//! 1. initialize M with the stacking + neuron-duplication pattern
//!    (Prop. 1: LiGO's family contains StackBERT/Net2Net, so this start
//!    point *is* the best non-learned baseline);
//! 2. run N (default 100) SGD-momentum steps on M;
//! 3. materialize Theta_large = M(Theta_small);
//! 4. account the extra FLOPs (Table 3) and hand the params to the trainer.
//!
//! Routing goes through the runtime's [`Backend`](crate::runtime::Backend):
//! when the `ligo_grad_{s}__{t}` / `ligo_apply_{s}__{t}` artifacts compile
//! (the `pjrt`-feature fast path), M trains against the expanded model's
//! *task loss* inside one fused XLA graph. Otherwise the manager runs the
//! **native task-loss path**: each M-step expands `Theta_large =
//! M(Theta_small)` ([`crate::growth::ligo::ligo_apply`]), runs the native
//! engine's forward/backward ([`crate::model::loss_and_grads`]) on a real
//! pretraining batch, and chains dL/dTheta_large through the fused
//! `B W A^T` width pass and the depth blends
//! ([`crate::growth::ligo::ligo_apply_backward`]) — the same objective as
//! the artifact path, no XLA required. The surrogate least-squares fit
//! ([`ligo_grow_surrogate`]) remains only as the fallback for when no task
//! batches exist (or an unsupported family).

use std::sync::Arc;

use crate::config::ModelConfig;
use crate::coordinator::flops;
use crate::coordinator::optim::Sgd;
use crate::error::{Context, Result};
use crate::log_info;
use crate::runtime::{Executable, Runtime};
use crate::tensor::{store::Store, Tensor};
use crate::util::rng::Rng;

/// Hyperparameters of the M-learning phase.
#[derive(Debug, Clone)]
pub struct LigoOptions {
    pub steps: usize,
    pub lr: f32,
    pub momentum: f32,
    pub init_noise: f32,
    pub seed: u64,
}

impl Default for LigoOptions {
    fn default() -> Self {
        // 100 steps of SGD, as in the paper (§3.2 "Training").
        LigoOptions { steps: 100, lr: 0.02, momentum: 0.9, init_noise: 0.01, seed: 0 }
    }
}

/// Result of a growth: the large params + cost accounting.
pub struct Grown {
    pub params: Store,
    pub extra_flops: f64,
    pub wall_s: f64,
    pub final_m_loss: f32,
    /// Which M-learning objective produced these params:
    /// "task-artifact" | "task-native" | "surrogate".
    pub objective: &'static str,
}

/// Initialize the LiGO parameter store M from manifest shapes: width
/// matrices get the cyclic duplication pattern, depth matrices the stacking
/// pattern (both + symmetry-breaking noise) — mirrors python ligo_init.
pub fn ligo_init_store(shapes: &[(String, Vec<usize>)], noise: f32, seed: u64) -> Store {
    let mut rng = Rng::new(seed ^ 0x11C0);
    let mut store = Store::new();
    for (name, shape) in shapes {
        assert_eq!(shape.len(), 2, "LiGO params are matrices: {name}");
        let (rows, cols) = (shape[0], shape[1]);
        let mut data = vec![0.0f32; rows * cols];
        for r in 0..rows {
            data[r * cols + (r % cols)] = 1.0;
        }
        for v in data.iter_mut() {
            *v += noise * rng.normal();
        }
        store.insert(name.clone(), Tensor::from_f32(shape, data));
    }
    store
}

/// Grow `small_params` into the target config by learning M on batches from
/// `batches` (the pretraining distribution). Tries the artifact fast path
/// first; falls back to the native path **only** when the backend cannot
/// load/compile the artifacts (default no-`pjrt` build, or artifacts not
/// built) — which still trains M on the true task loss via the native
/// engine. Errors from the M-training loop itself are real failures and
/// propagate — they must not silently switch the training objective.
pub fn ligo_grow(
    rt: &Runtime,
    small: &ModelConfig,
    large: &ModelConfig,
    small_params: &Store,
    batches: &mut dyn FnMut(usize) -> Store,
    opts: &LigoOptions,
) -> Result<Grown> {
    let pair = format!("{}__{}", small.name, large.name);
    let loaded = rt
        .load(&format!("ligo_grad_{pair}"))
        .and_then(|grad| rt.load(&format!("ligo_apply_{pair}")).map(|apply| (grad, apply)));
    match loaded {
        Ok((grad, apply)) => {
            ligo_train_artifact(&grad, &apply, small, large, small_params, batches, opts)
        }
        Err(e) => {
            log_info!(
                "LiGO artifacts unavailable for {}->{} ({e}); using the native engine",
                small.name,
                large.name
            );
            ligo_grow_native(small, large, small_params, batches, opts)
        }
    }
}

/// The `pjrt`-feature fast path: M trained on the expanded model's task
/// loss through the `ligo_grad_{s}__{t}` artifact, applied via
/// `ligo_apply_{s}__{t}`. No fallback: artifact-load errors surface here.
pub fn ligo_grow_artifact(
    rt: &Runtime,
    small: &ModelConfig,
    large: &ModelConfig,
    small_params: &Store,
    batches: &mut dyn FnMut(usize) -> Store,
    opts: &LigoOptions,
) -> Result<Grown> {
    let pair = format!("{}__{}", small.name, large.name);
    let grad = rt
        .load(&format!("ligo_grad_{pair}"))
        .with_context(|| format!("no ligo_grad artifact for pair {pair}"))?;
    let apply = rt.load(&format!("ligo_apply_{pair}"))?;
    ligo_train_artifact(&grad, &apply, small, large, small_params, batches, opts)
}

/// The M-training loop over loaded artifacts (shared by [`ligo_grow`] and
/// [`ligo_grow_artifact`]).
#[allow(clippy::too_many_arguments)]
fn ligo_train_artifact(
    grad: &Arc<Executable>,
    apply: &Arc<Executable>,
    small: &ModelConfig,
    large: &ModelConfig,
    small_params: &Store,
    batches: &mut dyn FnMut(usize) -> Store,
    opts: &LigoOptions,
) -> Result<Grown> {
    let timer = crate::util::timer::Timer::new();
    let mut m = ligo_init_store(&grad.manifest.shapes_of("ligo"), opts.init_noise, opts.seed);
    let mut sgd = Sgd::new(&m, opts.momentum);
    let mut last_loss = f32::NAN;
    for step in 0..opts.steps {
        let batch = batches(step);
        let out = grad.run(&[("ligo", &m), ("small", small_params), ("batch", &batch)])?;
        last_loss = out.scalar("loss").unwrap_or(f32::NAN);
        let grads = out.groups.get("grads").expect("ligo grads");
        // cosine-ish decay over the short M-learning phase (shared schedule)
        let lr = crate::growth::ligo::m_lr_at(opts.lr, step, opts.steps);
        sgd.step(&mut m, grads, lr);
        if step % 25 == 0 {
            log_info!("ligo M-step {step}: loss {last_loss:.4}");
        }
    }
    let out = apply.run(&[("ligo", &m), ("small", small_params)])?;
    let params = out
        .groups
        .get("out")
        .expect("ligo_apply returns params")
        .clone();
    let extra_flops = opts.steps as f64 * flops::ligo_step_flops(small, large)
        + flops::ligo_apply_flops(small, large);
    Ok(Grown {
        params,
        extra_flops,
        wall_s: timer.elapsed(),
        final_m_loss: last_loss,
        objective: "task-artifact",
    })
}

/// Does this batch carry the keys the native engine needs for `cfg`?
fn usable_task_batch(cfg: &ModelConfig, batch: &Store) -> bool {
    if cfg.is_vision() {
        batch.contains("images") && batch.contains("labels")
    } else {
        batch.contains("tokens") && batch.contains("labels")
    }
}

/// The native no-XLA route: true task-loss M-learning through the native
/// engine when task batches are available, else the surrogate fit. Family
/// support and batch shape are decided from batch 0; errors *inside* the
/// chosen M-training loop propagate (they must not switch the objective).
pub fn ligo_grow_native(
    small: &ModelConfig,
    large: &ModelConfig,
    small_params: &Store,
    batches: &mut dyn FnMut(usize) -> Store,
    opts: &LigoOptions,
) -> Result<Grown> {
    if crate::model::supports(large) && usable_task_batch(large, &batches(0)) {
        ligo_grow_task_native(small, large, small_params, batches, opts)
    } else {
        log_info!(
            "no task batches for {} -> {}; training M on the surrogate objective",
            small.name,
            large.name
        );
        ligo_grow_surrogate(small, large, small_params, opts)
    }
}

/// True task-loss M-learning without XLA (paper Algorithm 1): per step,
/// materialize `Theta_large = M(Theta_small)`, run the native engine's
/// forward/backward on a pretraining batch, chain dL/dTheta_large through
/// the expansion (`ligo_apply_backward`) into dL/dM, and take an
/// SGD-momentum step on M.
pub fn ligo_grow_task_native(
    small: &ModelConfig,
    large: &ModelConfig,
    small_params: &Store,
    batches: &mut dyn FnMut(usize) -> Store,
    opts: &LigoOptions,
) -> Result<Grown> {
    use crate::growth::ligo::{ligo_apply, ligo_apply_backward, ligo_init, m_lr_at};
    let timer = crate::util::timer::Timer::new();
    let mut m = ligo_init(small, large, opts.init_noise, opts.seed);
    let mut sgd = Sgd::new(&m, opts.momentum);
    let mut last_loss = f32::NAN;
    for step in 0..opts.steps {
        let batch = batches(step);
        let theta = ligo_apply(&m, small_params, small, large);
        let (loss, grads_theta, _metric) = crate::model::loss_and_grads(large, &theta, &batch)?;
        last_loss = loss;
        let dm = ligo_apply_backward(&m, small_params, &grads_theta, small, large);
        // the expanded model and its gradients die here every step —
        // recycle their (large-model-sized) buffers for the next iteration
        crate::tensor::arena::recycle_store(theta);
        crate::tensor::arena::recycle_store(grads_theta);
        // cosine-ish decay over the short M-learning phase (shared schedule)
        let lr = m_lr_at(opts.lr, step, opts.steps);
        sgd.step(&mut m, &dm, lr);
        crate::tensor::arena::recycle_store(dm);
        if step % 25 == 0 {
            log_info!("ligo M-step {step} (native task loss): loss {last_loss:.4}");
        }
    }
    let params = ligo_apply(&m, small_params, small, large);
    if opts.steps == 0 {
        last_loss = crate::model::loss_only(large, &params, &batches(0))?.0;
    }
    let extra_flops = opts.steps as f64 * flops::ligo_step_flops(small, large)
        + flops::ligo_apply_flops(small, large);
    Ok(Grown {
        params,
        extra_flops,
        wall_s: timer.elapsed(),
        final_m_loss: last_loss,
        objective: "task-native",
    })
}

/// The surrogate fallback: the [`crate::growth::ligo::Ligo`] operator
/// (least-squares M-learning against the StackBERT+Interpolation ensemble),
/// with FLOPs accounted analytically — M-steps backprop only through the
/// expansion, not a large-model fwd/bwd, hence the cheaper per-step cost.
pub fn ligo_grow_surrogate(
    small: &ModelConfig,
    large: &ModelConfig,
    small_params: &Store,
    opts: &LigoOptions,
) -> Result<Grown> {
    let timer = crate::util::timer::Timer::new();
    let op = crate::growth::ligo::Ligo {
        steps: opts.steps,
        lr: opts.lr,
        momentum: opts.momentum,
        noise: opts.init_noise,
        seed: opts.seed,
    };
    let (params, final_m_loss) = op.grow_with_loss(small_params, small, large);
    let extra_flops = opts.steps as f64 * flops::ligo_native_step_flops(small, large)
        + flops::ligo_apply_flops(small, large);
    Ok(Grown {
        params,
        extra_flops,
        wall_s: timer.elapsed(),
        final_m_loss,
        objective: "surrogate",
    })
}

/// Depth-only / width-only variants (Fig. 6) use the same entry point with
/// the ablation pairs (bert_d3w72 -> bert_base, bert_d6w48 -> bert_base);
/// M simply lacks the other direction's parameters.

#[cfg(test)]
mod tests {
    use super::*;
    use crate::growth::testutil::{mk_cfg, small_store};

    #[test]
    fn init_pattern_is_stack_plus_noise() {
        let shapes = vec![
            ("w_q".to_string(), vec![6, 3]),
            ("B_emb".to_string(), vec![12, 8]),
        ];
        let m = ligo_init_store(&shapes, 0.0, 0);
        let w = m.expect("w_q");
        // rows 0..3 identity, rows 3..6 repeat (stacking pattern)
        for r in 0..6 {
            for c in 0..3 {
                let want = if c == r % 3 { 1.0 } else { 0.0 };
                assert_eq!(w.at2(r, c), want, "r{r} c{c}");
            }
        }
        let b = m.expect("B_emb");
        assert_eq!(b.at2(9, 1), 1.0); // 9 % 8 = 1
    }

    #[test]
    fn noise_breaks_symmetry_deterministically() {
        let shapes = vec![("B_emb".to_string(), vec![4, 2])];
        let a = ligo_init_store(&shapes, 0.01, 7);
        let b = ligo_init_store(&shapes, 0.01, 7);
        let c = ligo_init_store(&shapes, 0.01, 8);
        assert_eq!(a.expect("B_emb"), b.expect("B_emb"));
        assert_ne!(a.expect("B_emb"), c.expect("B_emb"));
    }

    #[test]
    fn default_options_match_paper() {
        assert_eq!(LigoOptions::default().steps, 100);
    }

    fn mk_batch(cfg: &ModelConfig, seed: u64) -> Store {
        let mut rng = crate::util::rng::Rng::new(seed);
        let (b, s) = (cfg.batch, cfg.seq);
        let tokens: Vec<i32> = (0..b * s).map(|_| rng.below(cfg.vocab) as i32).collect();
        let labels: Vec<i32> = tokens
            .iter()
            .map(|&t| if rng.coin(0.3) { t } else { -1 })
            .collect();
        let mut st = Store::new();
        st.insert("tokens", Tensor::from_i32(&[b, s], tokens));
        st.insert("labels", Tensor::from_i32(&[b, s], labels));
        st
    }

    #[test]
    fn ligo_grow_without_artifacts_trains_m_on_the_task_loss() {
        let rt = Runtime::cpu(std::env::temp_dir().join("ligo_gm_no_artifacts")).unwrap();
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(4, 12, 3);
        let small = small_store(&cs);
        let opts = LigoOptions { steps: 5, ..Default::default() };
        let mut batches = |s: usize| mk_batch(&mk_cfg(4, 12, 3), 100 + s as u64);
        let grown = ligo_grow(&rt, &cs, &cl, &small, &mut batches, &opts).unwrap();
        assert_eq!(grown.objective, "task-native");
        assert!(grown.final_m_loss.is_finite());
        assert!(grown.extra_flops > 0.0);
        assert_eq!(grown.params.len(), small_store(&cl).len());
        assert_eq!(grown.params.expect("L03_q_w").shape, vec![12, 12]);
    }

    #[test]
    fn empty_batches_fall_back_to_the_surrogate_objective() {
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(4, 12, 3);
        let small = small_store(&cs);
        let opts = LigoOptions { steps: 5, ..Default::default() };
        let mut batches = |_s: usize| Store::new();
        let grown = ligo_grow_native(&cs, &cl, &small, &mut batches, &opts).unwrap();
        assert_eq!(grown.objective, "surrogate");
        assert!(grown.final_m_loss.is_finite());
    }

    #[test]
    fn task_native_m_learning_descends_the_task_loss() {
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(4, 12, 3);
        let small = small_store(&cs);
        // the same fixed batch each step: loss at step N must beat step 0
        let mut batches = |_s: usize| mk_batch(&mk_cfg(4, 12, 3), 7);
        let l0 = ligo_grow_task_native(
            &cs,
            &cl,
            &small,
            &mut batches,
            &LigoOptions { steps: 0, ..Default::default() },
        )
        .unwrap();
        let ln = ligo_grow_task_native(
            &cs,
            &cl,
            &small,
            &mut batches,
            &LigoOptions { steps: 20, ..Default::default() },
        )
        .unwrap();
        assert!(l0.final_m_loss.is_finite() && ln.final_m_loss.is_finite());
        assert!(
            ln.final_m_loss < l0.final_m_loss,
            "task-loss M-learning must descend: {} -> {}",
            l0.final_m_loss,
            ln.final_m_loss
        );
    }

    #[test]
    fn native_flops_accounting_scales_with_steps_and_objective() {
        let cs = mk_cfg(2, 8, 2);
        let cl = mk_cfg(4, 12, 3);
        let small = small_store(&cs);
        let g5 =
            ligo_grow_surrogate(&cs, &cl, &small, &LigoOptions { steps: 5, ..Default::default() })
                .unwrap();
        let g9 =
            ligo_grow_surrogate(&cs, &cl, &small, &LigoOptions { steps: 9, ..Default::default() })
                .unwrap();
        assert!(g9.extra_flops > g5.extra_flops);
        assert_eq!(g5.objective, "surrogate");
        // a task-native step costs more FLOPs than a surrogate step (it
        // pays the large-model fwd/bwd on top of the expansion backprop)
        let mut batches = |_s: usize| mk_batch(&mk_cfg(4, 12, 3), 9);
        let t5 = ligo_grow_task_native(
            &cs,
            &cl,
            &small,
            &mut batches,
            &LigoOptions { steps: 5, ..Default::default() },
        )
        .unwrap();
        assert!(t5.extra_flops > g5.extra_flops);
    }
}
