//! # LiGO — Learning to Grow Pretrained Models for Efficient Transformer Training
//!
//! A full-system reproduction of Wang et al. (ICLR 2023) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L1 (Pallas)** — fused LiGO width-expansion and flash-attention kernels
//!   (`python/compile/kernels/`), lowered AOT.
//! * **L2 (JAX)** — the transformer families and the LiGO operator
//!   (`python/compile/`), lowered once to HLO text artifacts.
//! * **L3 (this crate)** — the coordinator: a pluggable runtime (the
//!   `runtime::Backend` trait; PJRT behind the off-by-default `pjrt`
//!   feature, with a **native transformer engine** (`model`) that
//!   synthesizes `fwd_*`/`grad_*` executables when artifacts are absent),
//!   optimizer, data pipeline, the growth-operator zoo including a fully
//!   native LiGO port with true task-loss M-learning, the LiGO growth
//!   manager, experiment harness and CLI. Python never runs at runtime, and
//!   the default build needs neither Python artifacts nor XLA.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for results.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod eval;
pub mod experiments;
pub mod growth;
pub mod model;
pub mod runtime;
pub mod search;
pub mod tensor;
pub mod util;

pub use config::{ModelConfig, Registry, TrainConfig};
pub use runtime::Runtime;
pub use tensor::store::Store;
pub use tensor::Tensor;
