//! Static shape verification: the symbolic twin of the autodiff [`Tape`].
//!
//! Two layers live here:
//!
//! * [`rules`] — pure, `Result`-returning shape rules, one per tape op.
//!   They are the **single source of truth** for operand validation: the
//!   real [`Tape`](super::tape::Tape) constructors call them before any
//!   kernel runs (turning what used to be kernel `assert_eq!` panics into
//!   typed [`crate::error::Error`]s with op/node context), and the symbolic
//!   interpreter below replays them with no data at all.
//! * [`ShapeTape`] — an abstract interpreter over shape-only tensors. It
//!   mirrors the real tape's lowering decisions exactly (fused vs. unfused
//!   linear chains, the streaming vs. materialized LM head), so a symbolic
//!   replay appends the **same node sequence** the real forward would —
//!   asserted node-for-node against `Tape::len()` in this module's tests.
//!
//! [`summarize`] / [`summarize_with`] replay the full family graphs
//! (bert/gpt/probe text, vit/cait vision — the same call sequences as
//! `text.rs` / `vision.rs`) from a [`ModelConfig`] alone and produce a
//! [`GraphSummary`]: per-node shapes/dtypes/FLOPs plus totals (parameter
//! count, forward/backward FLOPs, a peak-arena-bytes estimate). No tensor
//! data is allocated and no kernel executes — verifying a growth plan's
//! every stage is microseconds, not a training step (see
//! [`crate::growth::verify`] and `ligo analyze`).
//!
//! The peak-bytes estimate counts what the arena actually retains: every
//! owned activation plus saved backward state (attention probabilities,
//! fused-GELU pre-activations, layernorm / LM-head statistics) — the tape
//! keeps all of it alive until drop — plus one transient gradient the size
//! of the largest node (backward recycles the rest as it walks).

use std::collections::BTreeMap;

use crate::bail;
use crate::config::ModelConfig;
use crate::error::{Context, Result};
use crate::tensor::numel;
use crate::tensor::ops::{self, Act, AttnShape};

/// Pure shape rules shared by the real [`Tape`](super::tape::Tape) and the
/// symbolic [`ShapeTape`]. Every rule validates its operands and returns
/// the output shape; errors state the violated constraint (callers add
/// op/node context).
pub mod rules {
    use super::*;

    fn two_d(s: &[usize], what: &str) -> Result<(usize, usize)> {
        if s.len() != 2 {
            bail!("{what} must be 2-D, got {s:?}");
        }
        Ok((s[0], s[1]))
    }

    /// `y = x @ w^T` for x (m, k) and w (n, k): the stored-projection
    /// matmul every linear lowers to.
    pub fn linear(x: &[usize], w: &[usize]) -> Result<Vec<usize>> {
        let (m, k) = two_d(x, "x")?;
        let (n, k2) = two_d(w, "w")?;
        if k != k2 {
            bail!("inner dims must match: x {x:?} @ w^T {w:?} ({k} vs {k2})");
        }
        Ok(vec![m, n])
    }

    /// Row-broadcast bias: b must have exactly one element per column.
    pub fn add_row(x: &[usize], b: &[usize]) -> Result<Vec<usize>> {
        let (_, d) = two_d(x, "x")?;
        if numel(b) != d {
            bail!("bias dim: {} elements do not broadcast over rows of width {d}", numel(b));
        }
        Ok(x.to_vec())
    }

    /// Elementwise residual add: shapes must be identical.
    pub fn add(a: &[usize], b: &[usize]) -> Result<Vec<usize>> {
        if a != b {
            bail!("operand shapes must match: {a:?} vs {b:?}");
        }
        Ok(a.to_vec())
    }

    /// `x + tile(t, reps)`: x must be exactly `reps` stacked copies of
    /// t's geometry.
    pub fn add_tiled(x: &[usize], t: &[usize], reps: usize) -> Result<Vec<usize>> {
        let (s, d) = two_d(t, "t")?;
        if x != [reps * s, d] {
            bail!("x {x:?} is not {reps} row blocks of t {t:?} (want {:?})", [reps * s, d]);
        }
        Ok(x.to_vec())
    }

    /// Row-broadcast scale (LayerScale): one element per column.
    pub fn mul_row(x: &[usize], v: &[usize]) -> Result<Vec<usize>> {
        let (_, d) = two_d(x, "x")?;
        if numel(v) != d {
            bail!("vector dim: {} elements do not broadcast over rows of width {d}", numel(v));
        }
        Ok(x.to_vec())
    }

    /// Row-wise layernorm: gain and bias carry one element per column.
    pub fn layernorm(x: &[usize], g: &[usize], b: &[usize]) -> Result<Vec<usize>> {
        let (_, d) = two_d(x, "x")?;
        if numel(g) != d {
            bail!("gain dim: {} elements for rows of width {d}", numel(g));
        }
        if numel(b) != d {
            bail!("bias dim: {} elements for rows of width {d}", numel(b));
        }
        Ok(x.to_vec())
    }

    /// Multi-head attention operand shapes (the `ops::attention_fwd`
    /// contract): q (batch*s_q, dim), k and v (batch*s_k, dim), dim
    /// divisible by the head count, causal masks square.
    pub fn attention(
        q: &[usize],
        k: &[usize],
        v: &[usize],
        sh: &AttnShape,
    ) -> Result<Vec<usize>> {
        let (_, dim) = two_d(q, "q")?;
        if sh.heads == 0 || dim % sh.heads != 0 {
            bail!("dim {dim} not divisible by {} heads", sh.heads);
        }
        if q != [sh.batch * sh.s_q, dim] {
            bail!("q shape {q:?} != (batch*s_q, dim) = {:?}", [sh.batch * sh.s_q, dim]);
        }
        if k != [sh.batch * sh.s_k, dim] {
            bail!("k shape {k:?} != (batch*s_k, dim) = {:?}", [sh.batch * sh.s_k, dim]);
        }
        if v != k {
            bail!("v shape {v:?} != k shape {k:?}");
        }
        if sh.causal && sh.s_q != sh.s_k {
            bail!("causal attention needs square scores (s_q {} vs s_k {})", sh.s_q, sh.s_k);
        }
        Ok(q.to_vec())
    }

    /// Embedding gather: emb must be a 2-D table; `n_ids` rows come out.
    /// (Per-id range checks need the id values and stay in the real tape.)
    pub fn gather(emb: &[usize], n_ids: usize) -> Result<Vec<usize>> {
        let (_, d) = two_d(emb, "emb")?;
        Ok(vec![n_ids, d])
    }

    /// A d-vector broadcast to (reps, d).
    pub fn broadcast_row(v: &[usize], reps: usize) -> Result<Vec<usize>> {
        Ok(vec![reps, numel(v)])
    }

    /// Per-batch-element sequence concat: a (batch*sa, d) ++ b (batch*sb, d).
    pub fn concat_seq(
        a: &[usize],
        b: &[usize],
        batch: usize,
        sa: usize,
        sb: usize,
    ) -> Result<Vec<usize>> {
        let (_, d) = two_d(a, "a")?;
        if a != [batch * sa, d] {
            bail!("a shape {a:?} != (batch*sa, d) = {:?}", [batch * sa, d]);
        }
        if b != [batch * sb, d] {
            bail!("b shape {b:?} != (batch*sb, d) = {:?}", [batch * sb, d]);
        }
        Ok(vec![batch * (sa + sb), d])
    }

    /// First sequence row of each batch element.
    pub fn seq_first(x: &[usize], batch: usize, s: usize) -> Result<Vec<usize>> {
        let (_, d) = two_d(x, "x")?;
        if x != [batch * s, d] {
            bail!("x shape {x:?} != (batch*s, d) = {:?}", [batch * s, d]);
        }
        Ok(vec![batch, d])
    }

    /// Mean over the s sequence rows of each batch element.
    pub fn seq_mean(x: &[usize], batch: usize, s: usize) -> Result<Vec<usize>> {
        if s == 0 {
            bail!("sequence length must be > 0");
        }
        seq_first(x, batch, s)
    }

    /// Masked cross-entropy over logit rows: one label per row; scalar out.
    pub fn masked_xent(logits: &[usize], n_labels: usize) -> Result<Vec<usize>> {
        let (n, _) = two_d(logits, "logits")?;
        if n_labels != n {
            bail!("one label per logit row: {n_labels} labels for {n} rows");
        }
        Ok(vec![1])
    }

    /// Streaming fused LM head `x @ w^T (+ b)` + masked xent: scalar out.
    pub fn lm_head_xent(
        x: &[usize],
        w: &[usize],
        b: Option<&[usize]>,
        n_labels: usize,
    ) -> Result<Vec<usize>> {
        let logits = linear(x, w)?;
        if let Some(bs) = b {
            add_row(&logits, bs)?;
        }
        masked_xent(&logits, n_labels)
    }

    /// Streaming fused LM head `x @ w^T (+ b)` + top-k/top-p sampling:
    /// one token id per row (the `ops::lm_head_sample` contract — logits
    /// validated like [`lm_head_xent`] but never materialized).
    pub fn lm_head_sample(x: &[usize], w: &[usize], b: Option<&[usize]>) -> Result<Vec<usize>> {
        let logits = linear(x, w)?;
        if let Some(bs) = b {
            add_row(&logits, bs)?;
        }
        Ok(vec![logits[0]])
    }

    /// (B, H, W, C) images -> (B*T, patch*patch*C) rows; the image side
    /// must tile exactly.
    pub fn patchify(images: &[usize], patch: usize) -> Result<Vec<usize>> {
        if images.len() != 4 {
            bail!("images must be (batch, H, W, C), got {images:?}");
        }
        let (b, h, w, c) = (images[0], images[1], images[2], images[3]);
        if patch == 0 || h % patch != 0 || w % patch != 0 {
            bail!("image {h}x{w} does not tile into {patch}x{patch} patches");
        }
        Ok(vec![b * (h / patch) * (w / patch), patch * patch * c])
    }
}

/// One symbolic node: what the real tape would append, minus the data.
#[derive(Debug, Clone)]
pub struct NodeSummary {
    /// Op label (e.g. `linear_fused`, `attention`, `param`).
    pub op: &'static str,
    pub shape: Vec<usize>,
    /// Activation dtype — the native engine is f32 throughout.
    pub dtype: &'static str,
    /// Forward FLOPs of this node (multiply-accumulate = 2, the
    /// [`crate::coordinator::flops`] convention).
    pub flops: f64,
    /// Bytes the tape retains for this node until drop: the owned value
    /// plus saved backward state (probs/pre-activation/stats). Borrowed
    /// parameter leaves retain nothing.
    pub bytes: usize,
}

/// Totals of one symbolic forward/backward replay.
#[derive(Debug, Clone)]
pub struct GraphSummary {
    /// Config name the graph was built for.
    pub name: String,
    pub nodes: Vec<NodeSummary>,
    /// Parameter scalars (the `param_shapes` inventory).
    pub params: usize,
    pub fwd_flops: f64,
    /// Backward ~= 2x forward (the paper's accounting).
    pub bwd_flops: f64,
    /// Peak-arena estimate: all retained node bytes plus one transient
    /// gradient of the largest node.
    pub peak_bytes: usize,
}

impl GraphSummary {
    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// One printable report row.
    pub fn brief(&self) -> String {
        format!(
            "{:<18} {:>5} nodes {:>10} params {:>9.3} GFLOP/step {:>8.2} MiB peak",
            self.name,
            self.nodes.len(),
            self.params,
            (self.fwd_flops + self.bwd_flops) / 1e9,
            self.peak_bytes as f64 / (1024.0 * 1024.0)
        )
    }
}

/// Handle to a symbolic node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SVar(usize);

/// The shape-only abstract interpreter. Mirrors [`super::tape::Tape`]'s
/// lowering (including the fused/unfused branches) node for node; the
/// `fused` / `fused_xent` flags are explicit so a summary is deterministic
/// rather than depending on ambient env knobs.
pub struct ShapeTape {
    fused: bool,
    fused_xent: bool,
    nodes: Vec<NodeSummary>,
}

impl ShapeTape {
    pub fn new(fused: bool, fused_xent: bool) -> ShapeTape {
        ShapeTape { fused, fused_xent, nodes: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn shape(&self, v: SVar) -> &[usize] {
        &self.nodes[v.0].shape
    }

    /// Node-context string matching the real tape's error wrapping.
    fn ctx(&self, op: &str) -> String {
        format!("node {} ({op})", self.nodes.len())
    }

    fn push(&mut self, op: &'static str, shape: Vec<usize>, flops: f64, saved: usize) -> SVar {
        let bytes = 4 * numel(&shape) + saved;
        self.nodes.push(NodeSummary { op, shape, dtype: "f32", flops, bytes });
        SVar(self.nodes.len() - 1)
    }

    /// An owned leaf (batch-derived data: the patchified image rows).
    pub fn leaf(&mut self, shape: Vec<usize>) -> SVar {
        self.push("leaf", shape, 0.0, 0)
    }

    /// A borrowed parameter leaf: retains no arena bytes.
    pub fn param(&mut self, shape: Vec<usize>) -> SVar {
        self.nodes.push(NodeSummary { op: "param", shape, dtype: "f32", flops: 0.0, bytes: 0 });
        SVar(self.nodes.len() - 1)
    }

    /// Mirror of the real tape's shared linear lowering: one fused node,
    /// or the matmul_nt / add_row / gelu chain.
    fn linear_node(&mut self, x: SVar, w: SVar, b: Option<SVar>, act: Act) -> Result<SVar> {
        if self.fused {
            let out = rules::linear(self.shape(x), self.shape(w))
                .with_context(|| self.ctx("linear"))?;
            if let Some(bv) = b {
                rules::add_row(&out, self.shape(bv)).with_context(|| self.ctx("linear"))?;
            }
            let (m, n) = (out[0], out[1]);
            let k = self.shape(x)[1];
            let mut flops = 2.0 * (m * k * n) as f64;
            let mut saved = 0usize;
            if b.is_some() {
                flops += (m * n) as f64;
            }
            if act == Act::Gelu {
                flops += 10.0 * (m * n) as f64;
                saved = 4 * m * n; // the saved pre-activation
            }
            return Ok(self.push("linear_fused", out, flops, saved));
        }
        let out =
            rules::linear(self.shape(x), self.shape(w)).with_context(|| self.ctx("linear"))?;
        let (m, n) = (out[0], out[1]);
        let k = self.shape(x)[1];
        let mut v = self.push("matmul_nt", out, 2.0 * (m * k * n) as f64, 0);
        if let Some(bv) = b {
            v = self.add_row(v, bv)?;
        }
        if act == Act::Gelu {
            v = self.gelu(v);
        }
        Ok(v)
    }

    pub fn linear(&mut self, x: SVar, w: SVar) -> Result<SVar> {
        self.linear_node(x, w, None, Act::None)
    }

    pub fn linear_bias(&mut self, x: SVar, w: SVar, b: SVar) -> Result<SVar> {
        self.linear_node(x, w, Some(b), Act::None)
    }

    pub fn linear_bias_gelu(&mut self, x: SVar, w: SVar, b: SVar) -> Result<SVar> {
        self.linear_node(x, w, Some(b), Act::Gelu)
    }

    pub fn add_row(&mut self, x: SVar, b: SVar) -> Result<SVar> {
        let out = rules::add_row(self.shape(x), self.shape(b))
            .with_context(|| self.ctx("add_row"))?;
        let flops = numel(&out) as f64;
        Ok(self.push("add_row", out, flops, 0))
    }

    pub fn add(&mut self, a: SVar, b: SVar) -> Result<SVar> {
        let out = rules::add(self.shape(a), self.shape(b)).with_context(|| self.ctx("add"))?;
        let flops = numel(&out) as f64;
        Ok(self.push("add", out, flops, 0))
    }

    pub fn add_tiled(&mut self, x: SVar, t: SVar, reps: usize) -> Result<SVar> {
        let out = rules::add_tiled(self.shape(x), self.shape(t), reps)
            .with_context(|| self.ctx("add_tiled"))?;
        let flops = numel(&out) as f64;
        Ok(self.push("add_tiled", out, flops, 0))
    }

    pub fn mul_row(&mut self, x: SVar, v: SVar) -> Result<SVar> {
        let out = rules::mul_row(self.shape(x), self.shape(v))
            .with_context(|| self.ctx("mul_row"))?;
        let flops = numel(&out) as f64;
        Ok(self.push("mul_row", out, flops, 0))
    }

    pub fn gelu(&mut self, x: SVar) -> SVar {
        let out = self.shape(x).to_vec();
        let flops = 10.0 * numel(&out) as f64;
        self.push("gelu", out, flops, 0)
    }

    pub fn layernorm(&mut self, x: SVar, g: SVar, b: SVar) -> Result<SVar> {
        let out = rules::layernorm(self.shape(x), self.shape(g), self.shape(b))
            .with_context(|| self.ctx("layernorm"))?;
        let rows = out[0];
        let flops = 8.0 * numel(&out) as f64;
        Ok(self.push("layernorm", out, flops, 4 * rows * 2)) // saved (mean, rstd)
    }

    pub fn attention(&mut self, q: SVar, k: SVar, v: SVar, sh: AttnShape) -> Result<SVar> {
        let out = rules::attention(self.shape(q), self.shape(k), self.shape(v), &sh)
            .with_context(|| self.ctx("attention"))?;
        let dh = out[1] / sh.heads;
        let pairs = (sh.batch * sh.heads * sh.s_q * sh.s_k) as f64;
        let flops = 4.0 * pairs * dh as f64 + 5.0 * pairs;
        let probs = 4 * sh.batch * sh.heads * sh.s_q * sh.s_k; // saved probabilities
        Ok(self.push("attention", out, flops, probs))
    }

    pub fn gather(&mut self, emb: SVar, n_ids: usize) -> Result<SVar> {
        let out =
            rules::gather(self.shape(emb), n_ids).with_context(|| self.ctx("gather"))?;
        Ok(self.push("gather", out, 0.0, 0))
    }

    pub fn broadcast_row(&mut self, v: SVar, reps: usize) -> Result<SVar> {
        let out = rules::broadcast_row(self.shape(v), reps)
            .with_context(|| self.ctx("broadcast_row"))?;
        Ok(self.push("broadcast_row", out, 0.0, 0))
    }

    pub fn concat_seq(
        &mut self,
        a: SVar,
        b: SVar,
        batch: usize,
        sa: usize,
        sb: usize,
    ) -> Result<SVar> {
        let out = rules::concat_seq(self.shape(a), self.shape(b), batch, sa, sb)
            .with_context(|| self.ctx("concat_seq"))?;
        Ok(self.push("concat_seq", out, 0.0, 0))
    }

    pub fn seq_first(&mut self, x: SVar, batch: usize, s: usize) -> Result<SVar> {
        let out = rules::seq_first(self.shape(x), batch, s)
            .with_context(|| self.ctx("seq_first"))?;
        Ok(self.push("seq_first", out, 0.0, 0))
    }

    pub fn seq_mean(&mut self, x: SVar, batch: usize, s: usize) -> Result<SVar> {
        let out = rules::seq_mean(self.shape(x), batch, s)
            .with_context(|| self.ctx("seq_mean"))?;
        let flops = (batch * s * self.shape(x)[1]) as f64;
        Ok(self.push("seq_mean", out, flops, 0))
    }

    pub fn masked_xent(&mut self, logits: SVar, n_labels: usize) -> Result<SVar> {
        let shape = self.shape(logits).to_vec();
        let out = rules::masked_xent(&shape, n_labels)
            .with_context(|| self.ctx("masked_xent"))?;
        let flops = 5.0 * numel(&shape) as f64;
        Ok(self.push("masked_xent", out, flops, 0))
    }

    /// Mirror of the real tape's LM-head lowering: one streaming node
    /// (logits never materialized), or linear_bias + masked_xent.
    pub fn lm_head_xent(
        &mut self,
        x: SVar,
        w: SVar,
        b: Option<SVar>,
        n_labels: usize,
    ) -> Result<SVar> {
        if !self.fused_xent {
            let logits = match b {
                Some(bv) => self.linear_bias(x, w, bv)?,
                None => self.linear(x, w)?,
            };
            return self.masked_xent(logits, n_labels);
        }
        let bs = b.map(|bv| self.shape(bv).to_vec());
        let out = rules::lm_head_xent(self.shape(x), self.shape(w), bs.as_deref(), n_labels)
            .with_context(|| self.ctx("lm_head_xent"))?;
        let (rows, d) = (self.shape(x)[0], self.shape(x)[1]);
        let v = self.shape(w)[0];
        let flops = 2.0 * (rows * d * v) as f64 + 5.0 * (rows * v) as f64;
        Ok(self.push("lm_head_xent", out, flops, 4 * rows * 3)) // [max, lse, label] rows
    }

    /// Single-query attention against the paged KV cache (the
    /// `ops::attention_decode` contract): only q lives on the tape — the
    /// cached K/V rows are synthesized from `sh`, validated with the same
    /// [`rules::attention`] rule. No backward, so nothing is saved.
    pub fn attention_decode(&mut self, q: SVar, sh: AttnShape) -> Result<SVar> {
        let qs = self.shape(q).to_vec();
        if qs.len() != 2 {
            bail!("q must be 2-D, got {qs:?}");
        }
        let kshape = vec![sh.batch * sh.s_k, qs[1]];
        let out = rules::attention(&qs, &kshape, &kshape, &sh)
            .with_context(|| self.ctx("attention_decode"))?;
        let dh = out[1] / sh.heads;
        let pairs = (sh.batch * sh.heads * sh.s_q * sh.s_k) as f64;
        let flops = 4.0 * pairs * dh as f64 + 5.0 * pairs;
        Ok(self.push("attention_decode", out, flops, 0))
    }

    /// Mirror of `ops::lm_head_sample`: streaming head + top-k/top-p pick,
    /// one token id per row, logits never materialized, nothing saved.
    pub fn lm_head_sample(&mut self, x: SVar, w: SVar, b: Option<SVar>) -> Result<SVar> {
        let bs = b.map(|bv| self.shape(bv).to_vec());
        let out = rules::lm_head_sample(self.shape(x), self.shape(w), bs.as_deref())
            .with_context(|| self.ctx("lm_head_sample"))?;
        let (rows, d) = (self.shape(x)[0], self.shape(x)[1]);
        let v = self.shape(w)[0];
        let flops = 2.0 * (rows * d * v) as f64 + 5.0 * (rows * v) as f64;
        Ok(self.push("lm_head_sample", out, flops, 0))
    }

    /// Close a decode replay: no backward, and the peak model is the
    /// serving one — decode recycles every activation per layer, so the
    /// footprint is the KV cache (`4 * 2 * layers * kv_tokens * dim`
    /// bytes) plus one block's transient working set, not the training
    /// tape's full retained-activation sum.
    fn finish_decode(
        self,
        cfg: &ModelConfig,
        phase: &'static str,
        out: SVar,
        kv_tokens: usize,
        working: usize,
    ) -> Result<GraphSummary> {
        if self.shape(out).len() != 1 {
            bail!("sampled tokens must be rank-1, got {:?}", self.shape(out));
        }
        let params: usize = super::param_shapes(cfg).iter().map(|(_, s)| numel(s)).sum();
        let fwd_flops: f64 = self.nodes.iter().map(|n| n.flops).sum();
        let kv_bytes = 4 * 2 * cfg.layers * kv_tokens * cfg.dim;
        Ok(GraphSummary {
            name: format!("{}+{phase}", cfg.name),
            nodes: self.nodes,
            params,
            fwd_flops,
            bwd_flops: 0.0,
            peak_bytes: kv_bytes + working,
        })
    }

    /// Close the replay: totals + the peak-arena estimate.
    fn finish(self, cfg: &ModelConfig, loss: SVar) -> Result<GraphSummary> {
        if numel(self.shape(loss)) != 1 {
            bail!("loss must be scalar, got {:?}", self.shape(loss));
        }
        let params: usize =
            super::param_shapes(cfg).iter().map(|(_, s)| numel(s)).sum();
        let fwd_flops: f64 = self.nodes.iter().map(|n| n.flops).sum();
        let retained: usize = self.nodes.iter().map(|n| n.bytes).sum();
        let largest = self.nodes.iter().map(|n| 4 * numel(&n.shape)).max().unwrap_or(0);
        Ok(GraphSummary {
            name: cfg.name.clone(),
            nodes: self.nodes,
            params,
            fwd_flops,
            bwd_flops: 2.0 * fwd_flops,
            peak_bytes: retained + largest,
        })
    }
}

fn svar(vars: &BTreeMap<String, SVar>, name: &str) -> Result<SVar> {
    vars.get(name)
        .copied()
        .with_context(|| format!("symbolic params missing tensor '{name}'"))
}

/// Symbolic twin of `text::preln_block` — same call sequence, same node
/// count.
fn sym_preln_block(
    st: &mut ShapeTape,
    vars: &BTreeMap<String, SVar>,
    prefix: &str,
    x: SVar,
    sh: AttnShape,
    layerscale: bool,
) -> Result<SVar> {
    let h = {
        let g = svar(vars, &format!("{prefix}ln1_g"))?;
        let b = svar(vars, &format!("{prefix}ln1_b"))?;
        st.layernorm(x, g, b)?
    };
    let qkv = |n: &str| format!("{prefix}{n}");
    let q = st.linear_bias(h, svar(vars, &qkv("q_w"))?, svar(vars, &qkv("q_b"))?)?;
    let k = st.linear_bias(h, svar(vars, &qkv("k_w"))?, svar(vars, &qkv("k_b"))?)?;
    let v = st.linear_bias(h, svar(vars, &qkv("v_w"))?, svar(vars, &qkv("v_b"))?)?;
    let att = st.attention(q, k, v, sh)?;
    let mut o = st.linear_bias(
        att,
        svar(vars, &format!("{prefix}o_w"))?,
        svar(vars, &format!("{prefix}o_b"))?,
    )?;
    if layerscale {
        o = st.mul_row(o, svar(vars, &format!("{prefix}ls1"))?)?;
    }
    let x = st.add(x, o)?;
    let h2 = {
        let g = svar(vars, &format!("{prefix}ln2_g"))?;
        let b = svar(vars, &format!("{prefix}ln2_b"))?;
        st.layernorm(x, g, b)?
    };
    let a = st.linear_bias_gelu(
        h2,
        svar(vars, &format!("{prefix}fc1_w"))?,
        svar(vars, &format!("{prefix}fc1_b"))?,
    )?;
    let mut f2 = st.linear_bias(
        a,
        svar(vars, &format!("{prefix}fc2_w"))?,
        svar(vars, &format!("{prefix}fc2_b"))?,
    )?;
    if layerscale {
        f2 = st.mul_row(f2, svar(vars, &format!("{prefix}ls2"))?)?;
    }
    st.add(x, f2)
}

/// Symbolic twin of `text::text_loss`.
fn sym_text_loss(
    st: &mut ShapeTape,
    vars: &BTreeMap<String, SVar>,
    cfg: &ModelConfig,
) -> Result<SVar> {
    if cfg.vocab == 0 || cfg.seq == 0 {
        bail!("text config '{}' needs vocab > 0 and seq > 0", cfg.name);
    }
    let (b, s) = (cfg.batch, cfg.seq);
    let x0 = st.gather(svar(vars, "emb_tok")?, b * s)?;
    let mut x = st.add_tiled(x0, svar(vars, "emb_pos")?, b)?;
    let sh = AttnShape {
        batch: b,
        heads: cfg.heads,
        s_q: s,
        s_k: s,
        causal: cfg.family == "gpt",
    };
    for l in 0..cfg.layers {
        x = sym_preln_block(st, vars, &format!("L{l:02}_"), x, sh, false)?;
    }
    let xf = st.layernorm(x, svar(vars, "final_ln_g")?, svar(vars, "final_ln_b")?)?;
    if cfg.n_classes > 0 {
        let pooled = st.seq_mean(xf, b, s)?;
        st.lm_head_xent(pooled, svar(vars, "head_w")?, Some(svar(vars, "head_b")?), b)
    } else {
        st.lm_head_xent(xf, svar(vars, "emb_tok")?, Some(svar(vars, "mlm_bias")?), b * s)
    }
}

/// Symbolic twin of `vision::class_attn_block`.
fn sym_class_attn_block(
    st: &mut ShapeTape,
    vars: &BTreeMap<String, SVar>,
    prefix: &str,
    cls: SVar,
    patches: SVar,
    batch: usize,
    t: usize,
    heads: usize,
) -> Result<SVar> {
    let xs = st.concat_seq(cls, patches, batch, 1, t)?;
    let ln1g = svar(vars, &format!("{prefix}ln1_g"))?;
    let ln1b = svar(vars, &format!("{prefix}ln1_b"))?;
    let hq = st.layernorm(cls, ln1g, ln1b)?;
    let hkv = st.layernorm(xs, ln1g, ln1b)?;
    let qkv = |n: &str| format!("{prefix}{n}");
    let q = st.linear_bias(hq, svar(vars, &qkv("q_w"))?, svar(vars, &qkv("q_b"))?)?;
    let k = st.linear_bias(hkv, svar(vars, &qkv("k_w"))?, svar(vars, &qkv("k_b"))?)?;
    let v = st.linear_bias(hkv, svar(vars, &qkv("v_w"))?, svar(vars, &qkv("v_b"))?)?;
    let sh = AttnShape { batch, heads, s_q: 1, s_k: t + 1, causal: false };
    let att = st.attention(q, k, v, sh)?;
    let o = st.linear_bias(
        att,
        svar(vars, &format!("{prefix}o_w"))?,
        svar(vars, &format!("{prefix}o_b"))?,
    )?;
    let cls = st.add(cls, o)?;
    let h2 = {
        let g = svar(vars, &format!("{prefix}ln2_g"))?;
        let b = svar(vars, &format!("{prefix}ln2_b"))?;
        st.layernorm(cls, g, b)?
    };
    let a = st.linear_bias_gelu(
        h2,
        svar(vars, &format!("{prefix}fc1_w"))?,
        svar(vars, &format!("{prefix}fc1_b"))?,
    )?;
    let f2 = st.linear_bias(
        a,
        svar(vars, &format!("{prefix}fc2_w"))?,
        svar(vars, &format!("{prefix}fc2_b"))?,
    )?;
    st.add(cls, f2)
}

/// Symbolic twin of `vision::vision_loss`.
fn sym_vision_loss(
    st: &mut ShapeTape,
    vars: &BTreeMap<String, SVar>,
    cfg: &ModelConfig,
) -> Result<SVar> {
    if cfg.n_classes == 0 {
        bail!("vision config '{}' needs n_classes > 0", cfg.name);
    }
    let b = cfg.batch;
    let images = vec![b, cfg.img, cfg.img, cfg.channels];
    let patch_rows = rules::patchify(&images, cfg.patch)
        .with_context(|| format!("patchify for '{}'", cfg.name))?;
    let t = patch_rows[0] / b;
    let pv = st.leaf(patch_rows);
    let x = st.linear_bias(pv, svar(vars, "emb_patch_w")?, svar(vars, "emb_patch_b")?)?;
    let emb_cls = svar(vars, "emb_cls")?;
    let pos = svar(vars, "emb_pos")?;
    let rep = if cfg.family == "vit" {
        let cls = st.broadcast_row(emb_cls, b)?;
        let xc = st.concat_seq(cls, x, b, 1, t)?;
        let mut x = st.add_tiled(xc, pos, b)?;
        let sh = AttnShape { batch: b, heads: cfg.heads, s_q: t + 1, s_k: t + 1, causal: false };
        for l in 0..cfg.layers {
            x = sym_preln_block(st, vars, &format!("L{l:02}_"), x, sh, false)?;
        }
        let xf = st.layernorm(x, svar(vars, "final_ln_g")?, svar(vars, "final_ln_b")?)?;
        st.seq_first(xf, b, t + 1)?
    } else {
        let mut x = st.add_tiled(x, pos, b)?;
        let sh = AttnShape { batch: b, heads: cfg.heads, s_q: t, s_k: t, causal: false };
        for l in 0..cfg.layers {
            x = sym_preln_block(st, vars, &format!("L{l:02}_"), x, sh, true)?;
        }
        let mut cls = st.broadcast_row(emb_cls, b)?;
        for l in 0..cfg.cls_layers {
            cls = sym_class_attn_block(st, vars, &format!("C{l:02}_"), cls, x, b, t, cfg.heads)?;
        }
        st.layernorm(cls, svar(vars, "final_ln_g")?, svar(vars, "final_ln_b")?)?
    };
    st.lm_head_xent(rep, svar(vars, "head_w")?, Some(svar(vars, "head_b")?), b)
}

/// Symbolically replay `cfg`'s full forward/backward with explicit fused
/// flags (no data, no kernels) and summarize it. Errors are the same typed
/// shape diagnostics the real graph construction raises.
pub fn summarize_with(cfg: &ModelConfig, fused: bool, fused_xent: bool) -> Result<GraphSummary> {
    if !super::supports(cfg) {
        bail!("native model engine does not support family '{}'", cfg.family);
    }
    let mut st = ShapeTape::new(fused, fused_xent);
    let mut vars: BTreeMap<String, SVar> = BTreeMap::new();
    for (name, shape) in super::param_shapes(cfg) {
        let leaf = st.param(shape);
        vars.insert(name, leaf);
    }
    let loss = if cfg.is_vision() {
        sym_vision_loss(&mut st, &vars, cfg)
    } else {
        sym_text_loss(&mut st, &vars, cfg)
    }
    .with_context(|| format!("static shape verification of '{}'", cfg.name))?;
    st.finish(cfg, loss)
}

/// [`summarize_with`] under the engine's *current* lowering knobs — the
/// summary of the graph the next real forward would build.
pub fn summarize(cfg: &ModelConfig) -> Result<GraphSummary> {
    summarize_with(cfg, ops::fused_enabled(), ops::fused_xent_enabled())
}

/// Compact cost row of one config's training graph, derived from
/// [`summarize`]: the totals the growth-search static filter ranks and
/// budget-checks candidates by.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GraphCost {
    /// Parameter scalars.
    pub params: usize,
    /// One training step's FLOPs (forward + backward) per microbatch.
    pub step_flops: f64,
    /// Peak-arena estimate in bytes.
    pub peak_bytes: usize,
}

/// Memoized [`summarize`]-derived cost lookup. Plan-space enumeration asks
/// for the same endpoint config's cost once per candidate that shares it
/// (dozens of times per rung); the symbolic replay is cheap but not free,
/// so costs are cached process-wide — keyed by the full geometry *and* the
/// lowering knobs the summary depends on, never by the config's name
/// (synthesized search rungs are not registry entries).
pub fn cost_of(cfg: &ModelConfig) -> Result<GraphCost> {
    use std::sync::{Mutex, OnceLock};
    let (fused, fused_xent) = (ops::fused_enabled(), ops::fused_xent_enabled());
    let key = format!(
        "{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{fused}|{fused_xent}",
        cfg.family, cfg.layers, cfg.dim, cfg.heads, cfg.vocab, cfg.seq, cfg.batch,
        cfg.img, cfg.patch, cfg.channels, cfg.n_classes, cfg.cls_layers, cfg.ffn_mult,
    );
    static CACHE: OnceLock<Mutex<BTreeMap<String, GraphCost>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(BTreeMap::new()));
    if let Some(cost) = cache.lock().unwrap_or_else(|p| p.into_inner()).get(&key) {
        return Ok(*cost);
    }
    let s = summarize_with(cfg, fused, fused_xent)?;
    let cost = GraphCost {
        params: s.params,
        step_flops: s.fwd_flops + s.bwd_flops,
        peak_bytes: s.peak_bytes,
    };
    cache.lock().unwrap_or_else(|p| p.into_inner()).insert(key, cost);
    Ok(cost)
}

/// Which serving phase a decode summary covers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecodePhase {
    /// Prompt ingestion: one causal full-prefix forward over `tokens`
    /// rows, writing every layer's K/V into the cache.
    Prefill { tokens: usize },
    /// One incremental step at position `pos`, attending over the
    /// `pos + 1` cached K/V rows.
    Step { pos: usize },
}

/// Symbolic twin of one `decode::decode_step` transformer block: same node
/// sequence as [`sym_preln_block`] except attention reads the paged cache
/// through [`ShapeTape::attention_decode`] (K/V are not tape operands).
fn sym_decode_block(
    st: &mut ShapeTape,
    vars: &BTreeMap<String, SVar>,
    prefix: &str,
    x: SVar,
    sh: AttnShape,
) -> Result<SVar> {
    let h = {
        let g = svar(vars, &format!("{prefix}ln1_g"))?;
        let b = svar(vars, &format!("{prefix}ln1_b"))?;
        st.layernorm(x, g, b)?
    };
    let qkv = |n: &str| format!("{prefix}{n}");
    let q = st.linear_bias(h, svar(vars, &qkv("q_w"))?, svar(vars, &qkv("q_b"))?)?;
    let _k = st.linear_bias(h, svar(vars, &qkv("k_w"))?, svar(vars, &qkv("k_b"))?)?;
    let _v = st.linear_bias(h, svar(vars, &qkv("v_w"))?, svar(vars, &qkv("v_b"))?)?;
    let att = st.attention_decode(q, sh)?;
    let o = st.linear_bias(
        att,
        svar(vars, &format!("{prefix}o_w"))?,
        svar(vars, &format!("{prefix}o_b"))?,
    )?;
    let x = st.add(x, o)?;
    let h2 = {
        let g = svar(vars, &format!("{prefix}ln2_g"))?;
        let b = svar(vars, &format!("{prefix}ln2_b"))?;
        st.layernorm(x, g, b)?
    };
    let a = st.linear_bias_gelu(
        h2,
        svar(vars, &format!("{prefix}fc1_w"))?,
        svar(vars, &format!("{prefix}fc1_b"))?,
    )?;
    let f2 = st.linear_bias(
        a,
        svar(vars, &format!("{prefix}fc2_w"))?,
        svar(vars, &format!("{prefix}fc2_b"))?,
    )?;
    st.add(x, f2)
}

/// Symbolically replay the tape-free serving path of `decode.rs` and
/// summarize it — shapes, FLOPs, and the serving peak-bytes model (KV
/// cache + one block's working set; decode retains no activations and has
/// no backward). Both phases append the **same node count**: the training
/// graph's plus one, because decode splits the embedding into two gathers
/// + add (position rows are a gather, not a batch tile) and ends in
/// [`rules::lm_head_sample`] instead of the xent head — pinned against
/// [`summarize_with`] in this module's tests and `tests/analyze_shapes.rs`.
pub fn summarize_decode(cfg: &ModelConfig, phase: DecodePhase) -> Result<GraphSummary> {
    if cfg.family != "gpt" {
        bail!("decode graphs exist for the gpt family, not '{}' ('{}')", cfg.family, cfg.name);
    }
    if cfg.n_classes > 0 {
        bail!("decode needs the tied LM head; '{}' is a probe config", cfg.name);
    }
    if cfg.vocab == 0 || cfg.seq == 0 {
        bail!("decode config '{}' needs vocab > 0 and seq > 0", cfg.name);
    }
    let (rows, s_k, causal, tag) = match phase {
        DecodePhase::Prefill { tokens } => {
            if tokens == 0 || tokens > cfg.seq {
                bail!("prefill length {tokens} outside [1, {}] for '{}'", cfg.seq, cfg.name);
            }
            (tokens, tokens, true, "prefill")
        }
        DecodePhase::Step { pos } => {
            if pos >= cfg.seq {
                bail!("step position {pos} outside seq {} for '{}'", cfg.seq, cfg.name);
            }
            (1, pos + 1, false, "step")
        }
    };
    let mut st = ShapeTape::new(true, true);
    let mut vars: BTreeMap<String, SVar> = BTreeMap::new();
    for (name, shape) in super::param_shapes(cfg) {
        let leaf = st.param(shape);
        vars.insert(name, leaf);
    }
    let build = |st: &mut ShapeTape| -> Result<SVar> {
        let x0 = st.gather(svar(&vars, "emb_tok")?, rows)?;
        let p = st.gather(svar(&vars, "emb_pos")?, rows)?;
        let mut x = st.add(x0, p)?;
        let sh = AttnShape { batch: 1, heads: cfg.heads, s_q: rows, s_k, causal };
        for l in 0..cfg.layers {
            let prefix = format!("L{l:02}_");
            x = match phase {
                DecodePhase::Prefill { .. } => {
                    sym_preln_block(st, &vars, &prefix, x, sh, false)?
                }
                DecodePhase::Step { .. } => sym_decode_block(st, &vars, &prefix, x, sh)?,
            };
        }
        let xf = st.layernorm(x, svar(&vars, "final_ln_g")?, svar(&vars, "final_ln_b")?)?;
        st.lm_head_sample(xf, svar(&vars, "emb_tok")?, Some(svar(&vars, "mlm_bias")?))
    };
    let out = build(&mut st)
        .with_context(|| format!("static shape verification of '{}' {tag}", cfg.name))?;
    // One block's transient working set: x/h/q/k/v/att/o-sized rows (6),
    // the attention probabilities (scores for a step), and the fc1
    // activation — everything decode holds at once before recycling.
    let working = 4 * (6 * rows * cfg.dim + cfg.heads * rows * s_k + rows * cfg.ffn());
    st.finish_decode(cfg, tag, out, s_k, working)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::store::Store;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn text_cfg(family: &str, n_classes: usize) -> ModelConfig {
        ModelConfig {
            name: format!("tiny_{family}"),
            family: family.into(),
            layers: 2,
            dim: 8,
            heads: 2,
            vocab: 24,
            seq: 6,
            batch: 2,
            img: 0,
            patch: 0,
            channels: 3,
            n_classes,
            cls_layers: 0,
            ffn_mult: 4,
        }
    }

    #[test]
    fn cost_of_matches_summarize_and_ignores_the_name() {
        let cfg = text_cfg("bert", 0);
        let s = summarize(&cfg).unwrap();
        let c = cost_of(&cfg).unwrap();
        assert_eq!(c.params, s.params);
        assert_eq!(c.step_flops, s.fwd_flops + s.bwd_flops);
        assert_eq!(c.peak_bytes, s.peak_bytes);
        // cache keys on geometry, not name: a renamed clone hits the same row
        let mut renamed = cfg.clone();
        renamed.name = "synth_rung_x".into();
        assert_eq!(cost_of(&renamed).unwrap(), c);
        // an unsupported family still surfaces its typed error
        let mut bad = cfg;
        bad.family = "rnn".into();
        assert!(cost_of(&bad).is_err());
    }

    fn vision_cfg(family: &str) -> ModelConfig {
        ModelConfig {
            name: format!("tiny_{family}"),
            family: family.into(),
            layers: 2,
            dim: 8,
            heads: 2,
            vocab: 0,
            seq: 0,
            batch: 2,
            img: 8,
            patch: 4,
            channels: 3,
            n_classes: 3,
            cls_layers: usize::from(family == "cait"),
            ffn_mult: 4,
        }
    }

    fn batch_for(cfg: &ModelConfig, seed: u64) -> Store {
        let mut rng = Rng::new(seed);
        let mut st = Store::new();
        if cfg.is_vision() {
            let n = cfg.batch * cfg.img * cfg.img * cfg.channels;
            st.insert(
                "images",
                Tensor::from_f32(
                    &[cfg.batch, cfg.img, cfg.img, cfg.channels],
                    (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect(),
                ),
            );
            let labels: Vec<i32> =
                (0..cfg.batch).map(|_| rng.below(cfg.n_classes) as i32).collect();
            st.insert("labels", Tensor::from_i32(&[cfg.batch], labels));
        } else {
            let (b, s) = (cfg.batch, cfg.seq);
            let tokens: Vec<i32> = (0..b * s).map(|_| rng.below(cfg.vocab) as i32).collect();
            st.insert("tokens", Tensor::from_i32(&[b, s], tokens.clone()));
            if cfg.n_classes > 0 {
                let labels: Vec<i32> =
                    (0..b).map(|_| rng.below(cfg.n_classes) as i32).collect();
                st.insert("labels", Tensor::from_i32(&[b], labels));
            } else {
                let labels: Vec<i32> =
                    tokens.iter().map(|&t| if t % 3 == 0 { t } else { -1 }).collect();
                st.insert("labels", Tensor::from_i32(&[b, s], labels));
            }
        }
        st
    }

    /// The parity invariant behind the whole subsystem: the symbolic
    /// replay appends exactly as many nodes as the real tape, for every
    /// family and every fused/unfused lowering combination.
    #[test]
    fn symbolic_node_count_matches_real_tape_for_every_family_and_lowering() {
        let cfgs = [
            text_cfg("bert", 0),
            text_cfg("gpt", 0),
            text_cfg("bert", 3), // probe
            vision_cfg("vit"),
            vision_cfg("cait"),
        ];
        for cfg in &cfgs {
            let params = Store::det_init(&super::super::param_shapes(cfg), 1);
            let batch = batch_for(cfg, 2);
            for (fused, fused_xent) in
                [(true, true), (false, false), (true, false), (false, true)]
            {
                ops::set_fused_override(Some(fused));
                ops::set_fused_xent_override(Some(fused_xent));
                let (tape, _loss, _vars, _m) =
                    super::super::build(cfg, &params, &batch).unwrap();
                ops::set_fused_override(None);
                ops::set_fused_xent_override(None);
                let summary = summarize_with(cfg, fused, fused_xent).unwrap();
                assert_eq!(
                    summary.node_count(),
                    tape.len(),
                    "{} fused={fused} fused_xent={fused_xent}",
                    cfg.name
                );
            }
        }
    }

    #[test]
    fn summary_totals_are_positive_and_consistent() {
        for cfg in [text_cfg("bert", 0), vision_cfg("cait")] {
            let s = summarize_with(&cfg, true, true).unwrap();
            assert!(s.fwd_flops > 0.0);
            assert_eq!(s.bwd_flops, 2.0 * s.fwd_flops);
            assert!(s.params > 0);
            assert!(s.peak_bytes > 0);
            assert!(s.brief().contains(&cfg.name));
        }
    }

    #[test]
    fn symbolic_flops_agree_with_the_analytic_model_to_a_small_factor() {
        // Two independent FLOPs models (per-node symbolic vs. the analytic
        // paper-axis formula) must land in the same ballpark — this is the
        // cross-check that keeps either from drifting silently.
        for cfg in [
            crate::config::Registry::builtin().model("bert_base").unwrap().clone(),
            crate::config::Registry::builtin().model("vit_s").unwrap().clone(),
        ] {
            let sym = summarize_with(&cfg, true, true).unwrap().fwd_flops;
            let analytic = crate::coordinator::flops::forward_flops(&cfg);
            let ratio = sym / analytic;
            assert!(
                (0.3..3.0).contains(&ratio),
                "{}: symbolic {sym:e} vs analytic {analytic:e} (ratio {ratio})",
                cfg.name
            );
        }
    }

    #[test]
    fn streaming_head_dominates_peak_bytes_statically() {
        // The PR-5 acceptance property, statically: with the streaming head
        // the (rows, vocab) logits node never exists, so the symbolic peak
        // drops below the materialized chain's.
        let mut cfg = text_cfg("bert", 0);
        cfg.vocab = 512;
        cfg.seq = 32;
        let fused = summarize_with(&cfg, true, true).unwrap();
        let unfused = summarize_with(&cfg, true, false).unwrap();
        let logits_bytes = 4 * cfg.batch * cfg.seq * cfg.vocab;
        assert!(
            unfused.peak_bytes >= fused.peak_bytes + logits_bytes,
            "unfused {} vs fused {} (+logits {logits_bytes})",
            unfused.peak_bytes,
            fused.peak_bytes
        );
    }

    #[test]
    fn malformed_configs_get_typed_diagnostics_without_kernels() {
        // heads not dividing dim
        let mut cfg = text_cfg("bert", 0);
        cfg.heads = 3;
        let err = summarize_with(&cfg, true, true).unwrap_err().to_string();
        assert!(err.contains("not divisible"), "{err}");
        assert!(err.contains("attention"), "{err}");
        // zero vocab
        let mut cfg = text_cfg("bert", 0);
        cfg.vocab = 0;
        assert!(summarize_with(&cfg, true, true).is_err());
        // image that does not tile into patches
        let mut cfg = vision_cfg("vit");
        cfg.img = 10;
        let err = summarize_with(&cfg, true, true).unwrap_err().to_string();
        assert!(err.contains("does not tile"), "{err}");
        // unsupported family
        let mut cfg = text_cfg("bert", 0);
        cfg.family = "rnn".into();
        assert!(summarize_with(&cfg, true, true).is_err());
    }

    #[test]
    fn decode_summaries_pin_node_counts_against_training() {
        let cfg = text_cfg("gpt", 0);
        let train = summarize_with(&cfg, true, true).unwrap();
        let pre = summarize_decode(&cfg, DecodePhase::Prefill { tokens: cfg.seq }).unwrap();
        let step = summarize_decode(&cfg, DecodePhase::Step { pos: cfg.seq - 1 }).unwrap();
        // both phases: training + 1 (two gathers + add for the embedding,
        // lm_head_sample for the head) — and equal to each other
        assert_eq!(pre.node_count(), train.node_count() + 1);
        assert_eq!(step.node_count(), pre.node_count());
        let p = super::super::param_shapes(&cfg).len();
        assert_eq!(pre.node_count(), p + 11 * cfg.layers + 5);
        // serving accounting: no backward, step much cheaper than prefill
        assert_eq!(step.bwd_flops, 0.0);
        assert!(step.fwd_flops > 0.0 && step.fwd_flops < pre.fwd_flops);
        // the KV cache grows with the attended prefix
        let s0 = summarize_decode(&cfg, DecodePhase::Step { pos: 0 }).unwrap();
        assert!(s0.peak_bytes < step.peak_bytes);
        assert!(pre.name.ends_with("+prefill"), "{}", pre.name);
        assert!(step.name.ends_with("+step"), "{}", step.name);
        assert!(step.nodes.iter().any(|n| n.op == "attention_decode"));
        assert!(step.nodes.iter().any(|n| n.op == "lm_head_sample"));
        assert!(pre.nodes.iter().all(|n| n.op != "attention_decode"));
    }

    #[test]
    fn decode_summaries_reject_bad_phases_and_families() {
        let cfg = text_cfg("gpt", 0);
        assert!(summarize_decode(&cfg, DecodePhase::Prefill { tokens: 0 }).is_err());
        assert!(summarize_decode(&cfg, DecodePhase::Prefill { tokens: cfg.seq + 1 }).is_err());
        assert!(summarize_decode(&cfg, DecodePhase::Step { pos: cfg.seq }).is_err());
        assert!(summarize_decode(&text_cfg("bert", 0), DecodePhase::Step { pos: 0 }).is_err());
        let err = summarize_decode(&text_cfg("gpt", 3), DecodePhase::Step { pos: 0 })
            .unwrap_err()
            .to_string();
        assert!(err.contains("probe"), "{err}");
    }

    #[test]
    fn rules_reject_each_operand_violation() {
        assert!(rules::linear(&[2, 3], &[4, 5]).is_err());
        assert_eq!(rules::linear(&[2, 3], &[4, 3]).unwrap(), vec![2, 4]);
        assert!(rules::add_row(&[2, 3], &[4]).is_err());
        assert!(rules::add(&[2, 3], &[3, 2]).is_err());
        assert!(rules::add_tiled(&[6, 3], &[2, 3], 2).is_err());
        assert!(rules::mul_row(&[2, 3], &[2]).is_err());
        assert!(rules::layernorm(&[2, 3], &[3], &[2]).is_err());
        let sh = AttnShape { batch: 1, heads: 2, s_q: 3, s_k: 3, causal: true };
        assert!(rules::attention(&[3, 4], &[3, 4], &[3, 5], &sh).is_err());
        assert!(rules::concat_seq(&[2, 3], &[4, 3], 2, 1, 3).is_err());
        assert!(rules::seq_first(&[5, 3], 2, 3).is_err());
        assert!(rules::masked_xent(&[4, 7], 3).is_err());
        assert!(rules::lm_head_xent(&[4, 3], &[7, 3], Some(&[6]), 4).is_err());
        assert!(rules::lm_head_sample(&[4, 3], &[7, 3], Some(&[6])).is_err());
        assert_eq!(rules::lm_head_sample(&[4, 3], &[7, 3], Some(&[7])).unwrap(), vec![4]);
        assert!(rules::patchify(&[1, 9, 9, 3], 4).is_err());
    }
}
