//! Native vision-family forward passes: ViT (CLS token through the patch
//! stack) and CaiT (LayerScale'd patch stage, then a class-attention stage
//! where only the CLS stream is updated) — mirroring `encode_vision` in
//! `python/compile/transformer.py`.

use std::collections::BTreeMap;

use crate::bail;
use crate::config::ModelConfig;
use crate::error::Result;
use crate::tensor::arena;
use crate::tensor::ops::AttnShape;
use crate::tensor::store::Store;
use crate::tensor::Tensor;

use super::tape::{Tape, Var};
use super::text::preln_block;
use super::{head_accuracy, var};

/// (B, H, W, C) images -> (B*T, patch*patch*C) rows, T = (H/p)*(W/p).
/// Matches the python `_patchify` layout exactly.
pub(super) fn patchify(images: &Tensor, patch: usize) -> Tensor {
    let s = &images.shape;
    let (b, hh, ww, c) = (s[0], s[1], s[2], s[3]);
    let (nh, nw) = (hh / patch, ww / patch);
    let pdim = patch * patch * c;
    let iv = images.f32s();
    // alloc_scratch: the patch walk below writes every element exactly once
    let mut out = arena::alloc_scratch(b * nh * nw * pdim);
    let mut o = 0;
    for bi in 0..b {
        for ph in 0..nh {
            for pw in 0..nw {
                for dy in 0..patch {
                    let y = ph * patch + dy;
                    for dx in 0..patch {
                        let x = pw * patch + dx;
                        let src = ((bi * hh + y) * ww + x) * c;
                        out[o..o + c].copy_from_slice(&iv[src..src + c]);
                        o += c;
                    }
                }
            }
        }
    }
    Tensor::from_f32(&[b * nh * nw, pdim], out)
}

/// One CaiT class-attention block: the CLS stream (one token per batch
/// element) attends over [CLS; patches]; only CLS is updated. No LayerScale
/// (mirrors the python `_class_attn_block`).
#[allow(clippy::too_many_arguments)]
fn class_attn_block(
    tape: &mut Tape<'_>,
    vars: &BTreeMap<String, Var>,
    prefix: &str,
    cls: Var,
    patches: Var,
    batch: usize,
    t: usize,
    heads: usize,
) -> Result<Var> {
    let xs = tape.concat_seq(cls, patches, batch, 1, t)?;
    let ln1g = var(vars, &format!("{prefix}ln1_g"))?;
    let ln1b = var(vars, &format!("{prefix}ln1_b"))?;
    let hq = tape.layernorm(cls, ln1g, ln1b)?;
    let hkv = tape.layernorm(xs, ln1g, ln1b)?;
    let q = {
        let w = var(vars, &format!("{prefix}q_w"))?;
        let b = var(vars, &format!("{prefix}q_b"))?;
        tape.linear_bias(hq, w, b)?
    };
    let k = {
        let w = var(vars, &format!("{prefix}k_w"))?;
        let b = var(vars, &format!("{prefix}k_b"))?;
        tape.linear_bias(hkv, w, b)?
    };
    let v = {
        let w = var(vars, &format!("{prefix}v_w"))?;
        let b = var(vars, &format!("{prefix}v_b"))?;
        tape.linear_bias(hkv, w, b)?
    };
    let sh = AttnShape { batch, heads, s_q: 1, s_k: t + 1, causal: false };
    let att = tape.attention(q, k, v, sh)?;
    let o = {
        let w = var(vars, &format!("{prefix}o_w"))?;
        let b = var(vars, &format!("{prefix}o_b"))?;
        tape.linear_bias(att, w, b)?
    };
    let cls = tape.add(cls, o)?;
    let h2 = {
        let g = var(vars, &format!("{prefix}ln2_g"))?;
        let b = var(vars, &format!("{prefix}ln2_b"))?;
        tape.layernorm(cls, g, b)?
    };
    // FFN: fc1 + bias + GELU in one fused pass
    let a = {
        let w = var(vars, &format!("{prefix}fc1_w"))?;
        let b = var(vars, &format!("{prefix}fc1_b"))?;
        tape.linear_bias_gelu(h2, w, b)?
    };
    let f2 = {
        let w = var(vars, &format!("{prefix}fc2_w"))?;
        let b = var(vars, &format!("{prefix}fc2_b"))?;
        tape.linear_bias(a, w, b)?
    };
    tape.add(cls, f2)
}

/// Image-classification loss + accuracy for ViT/CaiT.
pub(super) fn vision_loss(
    tape: &mut Tape<'_>,
    vars: &BTreeMap<String, Var>,
    cfg: &ModelConfig,
    batch: &Store,
) -> Result<(Var, Option<f32>)> {
    let Some(images) = batch.get("images") else {
        bail!("vision batch for '{}' missing 'images'", cfg.name)
    };
    let Some(labels) = batch.get("labels") else {
        bail!("vision batch for '{}' missing 'labels'", cfg.name)
    };
    if images.shape.len() != 4
        || images.shape[1] != cfg.img
        || images.shape[2] != cfg.img
        || images.shape[3] != cfg.channels
    {
        bail!(
            "'images' must be (batch, {img}, {img}, {c}), got {:?}",
            images.shape,
            img = cfg.img,
            c = cfg.channels
        );
    }
    let b = images.shape[0];
    if labels.shape != vec![b] {
        bail!("vision labels must be ({b},), got {:?}", labels.shape);
    }
    let n_side = cfg.img / cfg.patch;
    let t = n_side * n_side;
    let pv = tape.leaf(patchify(images, cfg.patch));
    let x = {
        let w = var(vars, "emb_patch_w")?;
        let bb = var(vars, "emb_patch_b")?;
        tape.linear_bias(pv, w, bb)?
    };
    let emb_cls = var(vars, "emb_cls")?;
    let pos = var(vars, "emb_pos")?;
    let rep = if cfg.family == "vit" {
        // prepend CLS, add positions over T+1 tokens, run the stack
        let cls = tape.broadcast_row(emb_cls, b);
        let xc = tape.concat_seq(cls, x, b, 1, t)?;
        let mut x = tape.add_tiled(xc, pos, b)?;
        let sh = AttnShape {
            batch: b,
            heads: cfg.heads,
            s_q: t + 1,
            s_k: t + 1,
            causal: false,
        };
        for l in 0..cfg.layers {
            x = preln_block(tape, vars, &format!("L{l:02}_"), x, sh, false)?;
        }
        let xf = {
            let g = var(vars, "final_ln_g")?;
            let bb = var(vars, "final_ln_b")?;
            tape.layernorm(x, g, bb)?
        };
        tape.seq_first(xf, b, t + 1)?
    } else {
        // CaiT: LayerScale'd patch stage, then class-attention over frozen
        // patches; the final LN runs on the CLS stream only.
        let mut x = tape.add_tiled(x, pos, b)?;
        let sh = AttnShape {
            batch: b,
            heads: cfg.heads,
            s_q: t,
            s_k: t,
            causal: false,
        };
        for l in 0..cfg.layers {
            x = preln_block(tape, vars, &format!("L{l:02}_"), x, sh, true)?;
        }
        let mut cls = tape.broadcast_row(emb_cls, b);
        for l in 0..cfg.cls_layers {
            cls = class_attn_block(tape, vars, &format!("C{l:02}_"), cls, x, b, t, cfg.heads)?;
        }
        let g = var(vars, "final_ln_g")?;
        let bb = var(vars, "final_ln_b")?;
        tape.layernorm(cls, g, bb)?
    };
    // classifier head, streamed: loss and accuracy run tile-by-tile through
    // the fused LM-head kernels — no (batch, n_classes) logits tensor
    let w = var(vars, "head_w")?;
    let bb = var(vars, "head_b")?;
    let lbl = labels.i32s().to_vec();
    if let Some(&bad) = lbl.iter().find(|&&l| l >= cfg.n_classes as i32) {
        bail!("label {bad} outside {} classes for '{}'", cfg.n_classes, cfg.name);
    }
    let acc = head_accuracy(tape.value(rep), tape.value(w), Some(tape.value(bb)), &lbl);
    let loss = tape.lm_head_xent(rep, w, Some(bb), lbl)?;
    Ok((loss, Some(acc)))
}
