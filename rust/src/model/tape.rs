//! Minimal reverse-mode autodiff over [`Tensor`]s — the substrate of the
//! native model engine.
//!
//! A [`Tape`] is an append-only arena of nodes; every op evaluates eagerly
//! (so [`Tape::value`] is always available) and records what it needs for
//! the reverse sweep (layernorm statistics, attention probabilities, the
//! fused linear's pre-activation). [`Tape::backward`] walks the arena once
//! in reverse, accumulating gradients into every node the scalar root
//! depends on — shared leaves (e.g. the tied `emb_tok` used by both the
//! embedding gather and the LM head) accumulate from all of their uses
//! automatically.
//!
//! # Invariants
//!
//! * **Leaf ownership.** A tape holds two kinds of leaves: *owned* leaves
//!   ([`Tape::leaf`], for batch-derived tensors and tests) and *borrowed*
//!   parameter leaves ([`Tape::param`]), which reference the caller's
//!   tensors for the tape's lifetime `'p` — the forward pass copies **no
//!   parameter data**. Gradients are always accumulated into fresh owned
//!   buffers, never into leaves, so borrowed parameters are read-only
//!   throughout.
//! * **Topological replay order.** Nodes are appended in evaluation order
//!   and ops only ever reference earlier nodes, so arena order *is* a
//!   topological order; `backward` is a single reverse walk with no
//!   worklist, and each node's gradient is complete when the walk reaches
//!   it.
//! * **Buffer recycling.** Owned node values and saved backward state are
//!   returned to the thread-local [`arena`](crate::tensor::arena) when the
//!   tape drops, and `backward` recycles every intermediate gradient as
//!   soon as its last consumer has run. A buffer is recycled only once its
//!   owner dies — never while a [`Var`] can still observe it — so
//!   [`Tape::value`] results stay valid for the tape's whole life.
//!   Borrowed leaves are never recycled (the caller owns them).
//!
//! Activations are kept 2-D throughout: a transformer stream is flattened
//! to `(batch * seq, dim)` and the attention op carries the
//! (batch, heads, s_q, s_k) layout in its [`AttnShape`].
//!
//! # Typed shape errors
//!
//! Every fallible constructor validates its operands through the shared
//! [`rules`](super::shape::rules) *before* any kernel runs and returns
//! `Result<Var>`: a malformed graph surfaces as a typed
//! [`crate::error::Error`] naming the offending node ("node N (op): ...")
//! instead of a kernel panic mid-forward. The kernel-level `assert!`s in
//! [`crate::tensor::ops`] remain as backstops, but they are unreachable
//! through this API. The symbolic [`super::shape::ShapeTape`] replays the
//! same rules with no data at all.
//!
//! ```
//! use ligo::model::tape::Tape;
//! use ligo::tensor::Tensor;
//!
//! let w = Tensor::from_f32(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]);
//! let b = Tensor::from_f32(&[2], vec![0.5, -0.5]);
//! let mut tape = Tape::new();
//! let x = tape.leaf(Tensor::from_f32(&[1, 2], vec![2.0, 3.0]));
//! let wv = tape.param(&w); // borrowed: no copy of w
//! let bv = tape.param(&b);
//! let y = tape.linear_bias(x, wv, bv).unwrap(); // fused x @ w^T + b
//! assert_eq!(tape.value(y).f32s(), &[2.5, 2.5]);
//! let loss = tape.masked_xent(y, vec![0]).unwrap();
//! let grads = tape.backward(loss);
//! assert!(grads[wv.index()].is_some(), "params receive gradients");
//! ```

use super::shape::rules;
use crate::error::{Context, Error, Result};
use crate::tensor::arena;
use crate::tensor::ops::{self, Act, AttnShape};
use crate::tensor::Tensor;

/// Handle to a tape node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Var(usize);

impl Var {
    /// Arena index (for looking up this node's gradient after `backward`).
    pub fn index(self) -> usize {
        self.0
    }
}

/// A node's forward value: computed (owned) or a borrowed parameter leaf.
enum Value<'p> {
    Owned(Tensor),
    Borrowed(&'p Tensor),
}

enum Op {
    Leaf,
    /// y = act(x @ w^T + b) — the fused dense layer on (out, in)-stored
    /// weights; `b` and the activation are optional. `pre` saves the
    /// pre-activation when `act` needs it for the backward (GELU).
    Linear { x: Var, w: Var, b: Option<Var>, act: Act, pre: Option<Tensor> },
    /// y = x + b with b broadcast over rows (the unfused bias path).
    AddRow { x: Var, b: Var },
    /// y = a + b, same shape.
    Add { a: Var, b: Var },
    /// y = x + tile(t, reps): t (s, d) added to each of `reps` row blocks.
    AddTiled { x: Var, t: Var, reps: usize },
    /// y = x * v with v broadcast over rows (CaiT LayerScale).
    MulRow { x: Var, v: Var },
    Gelu { x: Var },
    LayerNorm { x: Var, g: Var, b: Var, stats: Vec<f32> },
    Attention { q: Var, k: Var, v: Var, sh: AttnShape, probs: Tensor },
    /// y[i] = emb[ids[i]] — embedding row gather.
    Gather { emb: Var, ids: Vec<i32> },
    /// y = v (a d-vector) broadcast to (reps, d).
    BroadcastRow { v: Var, reps: usize },
    /// Per batch element: concat sa rows of `a` with sb rows of `b`.
    ConcatSeq { a: Var, b: Var, batch: usize, sa: usize, sb: usize },
    /// y[b] = x[b * s] — the first sequence position of each batch element.
    SeqFirst { x: Var, batch: usize, s: usize },
    /// y[b] = mean over the s sequence rows of batch element b.
    SeqMean { x: Var, batch: usize, s: usize },
    /// Scalar masked mean cross-entropy over the rows of `logits`.
    MaskedXent { logits: Var, labels: Vec<i32>, count: f32 },
    /// Scalar masked mean cross-entropy of the LM/classifier head
    /// `x @ w^T (+ b)` — streaming fused: the `(rows, vocab)` logits are
    /// never materialized; `stats` holds the per-row
    /// `[max, logsumexp, label logit]` triples (the backward rebuilds each
    /// softmax tile from the logsumexp slot).
    LmHeadXent { x: Var, w: Var, b: Option<Var>, labels: Vec<i32>, count: f32, stats: Vec<f32> },
}

struct Node<'p> {
    value: Value<'p>,
    op: Op,
}

/// The autodiff arena. See the module docs.
#[derive(Default)]
pub struct Tape<'p> {
    nodes: Vec<Node<'p>>,
}

/// Accumulate `t` into an optional gradient slot; an already-occupied slot
/// consumes (and recycles) `t`.
fn acc(slot: &mut Option<Tensor>, t: Tensor) {
    match slot {
        Some(a) => {
            debug_assert_eq!(a.shape, t.shape, "gradient shape mismatch");
            for (x, y) in a.f32s_mut().iter_mut().zip(t.f32s()) {
                *x += y;
            }
            arena::recycle(t);
        }
        None => *slot = Some(t),
    }
}

/// Column sums of a 2-D gradient (the broadcast-bias backward).
fn col_sums(g: &Tensor) -> Vec<f32> {
    let d = g.shape[1];
    let mut out = arena::alloc_zeroed(d);
    for row in g.f32s().chunks_exact(d) {
        for (a, &v) in out.iter_mut().zip(row) {
            *a += v;
        }
    }
    out
}

impl<'p> Tape<'p> {
    pub fn new() -> Tape<'p> {
        Tape::default()
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// The (eagerly computed) value of a node.
    pub fn value(&self, v: Var) -> &Tensor {
        match &self.nodes[v.0].value {
            Value::Owned(t) => t,
            Value::Borrowed(t) => t,
        }
    }

    fn push(&mut self, value: Tensor, op: Op) -> Var {
        self.nodes.push(Node { value: Value::Owned(value), op });
        Var(self.nodes.len() - 1)
    }

    /// An owned constant/input leaf (batch-derived tensors, tests).
    pub fn leaf(&mut self, t: Tensor) -> Var {
        self.push(t, Op::Leaf)
    }

    /// A borrowed parameter leaf: the tape references `t` for its lifetime
    /// instead of copying it. Gradients still land in owned buffers.
    pub fn param(&mut self, t: &'p Tensor) -> Var {
        self.nodes.push(Node { value: Value::Borrowed(t), op: Op::Leaf });
        Var(self.nodes.len() - 1)
    }

    /// Node-context prefix for shape diagnostics: the index the node would
    /// get if the op validated.
    fn ctx(&self, op: &str) -> String {
        format!("node {} ({op})", self.nodes.len())
    }

    /// Shared lowering of the linear family: one fused node when the fused
    /// kernel is enabled, the unfused linear/add/GELU chain otherwise.
    fn linear_node(&mut self, x: Var, w: Var, b: Option<Var>, act: Act) -> Result<Var> {
        if ops::fused_enabled() {
            let out = rules::linear(&self.value(x).shape, &self.value(w).shape)
                .with_context(|| self.ctx("linear"))?;
            if let Some(bv) = b {
                rules::add_row(&out, &self.value(bv).shape)
                    .with_context(|| self.ctx("linear"))?;
            }
            let bias = b.map(|bv| self.value(bv));
            let (y, pre) = ops::linear_fused(self.value(x), self.value(w), bias, act);
            return Ok(self.push(y, Op::Linear { x, w, b, act, pre }));
        }
        rules::linear(&self.value(x).shape, &self.value(w).shape)
            .with_context(|| self.ctx("linear"))?;
        let y = ops::matmul_nt(self.value(x), self.value(w));
        let mut out = self.push(y, Op::Linear { x, w, b: None, act: Act::None, pre: None });
        if let Some(bv) = b {
            out = self.add_row(out, bv)?;
        }
        if act == Act::Gelu {
            out = self.gelu(out);
        }
        Ok(out)
    }

    /// y = x @ w^T for x (n, in) and w (out, in) — the y = W x convention
    /// every stored projection uses.
    pub fn linear(&mut self, x: Var, w: Var) -> Result<Var> {
        self.linear_node(x, w, None, Act::None)
    }

    /// y = x @ w^T + b, fused ([`ops::linear_fused`]).
    pub fn linear_bias(&mut self, x: Var, w: Var, b: Var) -> Result<Var> {
        self.linear_node(x, w, Some(b), Act::None)
    }

    /// y = gelu(x @ w^T + b), fused — the transformer FFN's first half in
    /// one kernel pass.
    pub fn linear_bias_gelu(&mut self, x: Var, w: Var, b: Var) -> Result<Var> {
        self.linear_node(x, w, Some(b), Act::Gelu)
    }

    /// y = x + b with the bias broadcast over rows.
    pub fn add_row(&mut self, x: Var, b: Var) -> Result<Var> {
        rules::add_row(&self.value(x).shape, &self.value(b).shape)
            .with_context(|| self.ctx("add_row"))?;
        let (xv, bv) = (self.value(x), self.value(b));
        let d = xv.shape[1];
        let mut out = Tensor::from_f32(&xv.shape, arena::alloc_copy(xv.f32s()));
        for row in out.f32s_mut().chunks_exact_mut(d) {
            for (o, &bb) in row.iter_mut().zip(bv.f32s()) {
                *o += bb;
            }
        }
        Ok(self.push(out, Op::AddRow { x, b }))
    }

    /// y = a + b (same shape; the residual connection).
    pub fn add(&mut self, a: Var, b: Var) -> Result<Var> {
        rules::add(&self.value(a).shape, &self.value(b).shape)
            .with_context(|| self.ctx("add"))?;
        let out = ops::axpy(self.value(a), 1.0, self.value(b));
        Ok(self.push(out, Op::Add { a, b }))
    }

    /// y = x + tile(t, reps): adds t (s, d) to each of `reps` consecutive
    /// s-row blocks of x (the positional-embedding broadcast over batch).
    pub fn add_tiled(&mut self, x: Var, t: Var, reps: usize) -> Result<Var> {
        rules::add_tiled(&self.value(x).shape, &self.value(t).shape, reps)
            .with_context(|| self.ctx("add_tiled"))?;
        let (xv, tv) = (self.value(x), self.value(t));
        let (s, d) = (tv.shape[0], tv.shape[1]);
        let mut out = Tensor::from_f32(&xv.shape, arena::alloc_copy(xv.f32s()));
        let tvv = tv.f32s();
        for block in out.f32s_mut().chunks_exact_mut(s * d) {
            for (o, &tt) in block.iter_mut().zip(tvv) {
                *o += tt;
            }
        }
        Ok(self.push(out, Op::AddTiled { x, t, reps }))
    }

    /// y = x * v with v broadcast over rows (LayerScale).
    pub fn mul_row(&mut self, x: Var, v: Var) -> Result<Var> {
        rules::mul_row(&self.value(x).shape, &self.value(v).shape)
            .with_context(|| self.ctx("mul_row"))?;
        let (xv, vv) = (self.value(x), self.value(v));
        let d = xv.shape[1];
        let mut out = Tensor::from_f32(&xv.shape, arena::alloc_copy(xv.f32s()));
        for row in out.f32s_mut().chunks_exact_mut(d) {
            for (o, &m) in row.iter_mut().zip(vv.f32s()) {
                *o *= m;
            }
        }
        Ok(self.push(out, Op::MulRow { x, v }))
    }

    pub fn gelu(&mut self, x: Var) -> Var {
        let y = ops::gelu_fwd(self.value(x));
        self.push(y, Op::Gelu { x })
    }

    pub fn layernorm(&mut self, x: Var, g: Var, b: Var) -> Result<Var> {
        rules::layernorm(&self.value(x).shape, &self.value(g).shape, &self.value(b).shape)
            .with_context(|| self.ctx("layernorm"))?;
        let (y, stats) = ops::layernorm_fwd(self.value(x), self.value(g), self.value(b));
        Ok(self.push(y, Op::LayerNorm { x, g, b, stats }))
    }

    /// Multi-head softmax attention; see [`ops::attention_fwd`].
    pub fn attention(&mut self, q: Var, k: Var, v: Var, sh: AttnShape) -> Result<Var> {
        rules::attention(&self.value(q).shape, &self.value(k).shape, &self.value(v).shape, &sh)
            .with_context(|| self.ctx("attention"))?;
        let (out, probs) = ops::attention_fwd(self.value(q), self.value(k), self.value(v), &sh);
        Ok(self.push(out, Op::Attention { q, k, v, sh, probs }))
    }

    /// y[i] = emb[ids[i]] — token/row embedding lookup. Ids outside the
    /// table are a typed error naming the first offender.
    pub fn gather(&mut self, emb: Var, ids: Vec<i32>) -> Result<Var> {
        rules::gather(&self.value(emb).shape, ids.len())
            .with_context(|| self.ctx("gather"))?;
        let ev = self.value(emb);
        let (rows, d) = (ev.shape[0], ev.shape[1]);
        let evv = ev.f32s();
        // alloc_scratch: every row is fully overwritten below
        let mut out = arena::alloc_scratch(ids.len() * d);
        for (i_row, &id) in ids.iter().enumerate() {
            if id < 0 || id as usize >= rows {
                return Err(Error::msg(format!("gather id {id} outside [0, {rows})")))
                    .with_context(|| format!("node {} (gather)", self.nodes.len()));
            }
            let r = id as usize;
            out[i_row * d..(i_row + 1) * d].copy_from_slice(&evv[r * d..(r + 1) * d]);
        }
        let t = Tensor::from_f32(&[ids.len(), d], out);
        Ok(self.push(t, Op::Gather { emb, ids }))
    }

    /// y = v (a d-vector) broadcast to (reps, d) — the CLS token.
    pub fn broadcast_row(&mut self, v: Var, reps: usize) -> Var {
        let vv = self.value(v);
        let d = vv.numel();
        // alloc_scratch: every chunk is fully overwritten below
        let mut out = arena::alloc_scratch(reps * d);
        for chunk in out.chunks_exact_mut(d) {
            chunk.copy_from_slice(vv.f32s());
        }
        let t = Tensor::from_f32(&[reps, d], out);
        self.push(t, Op::BroadcastRow { v, reps })
    }

    /// Per batch element, concat sa rows of `a` with sb rows of `b` along
    /// the sequence axis (CLS-token prepend / class-attention key stream).
    pub fn concat_seq(
        &mut self,
        a: Var,
        b: Var,
        batch: usize,
        sa: usize,
        sb: usize,
    ) -> Result<Var> {
        rules::concat_seq(&self.value(a).shape, &self.value(b).shape, batch, sa, sb)
            .with_context(|| self.ctx("concat_seq"))?;
        let (av, bv) = (self.value(a), self.value(b));
        let d = av.shape[1];
        let (avv, bvv) = (av.f32s(), bv.f32s());
        // alloc_scratch: every block is fully overwritten below
        let mut out = arena::alloc_scratch(batch * (sa + sb) * d);
        for bi in 0..batch {
            let base = bi * (sa + sb) * d;
            out[base..base + sa * d].copy_from_slice(&avv[bi * sa * d..(bi + 1) * sa * d]);
            out[base + sa * d..base + (sa + sb) * d]
                .copy_from_slice(&bvv[bi * sb * d..(bi + 1) * sb * d]);
        }
        let t = Tensor::from_f32(&[batch * (sa + sb), d], out);
        Ok(self.push(t, Op::ConcatSeq { a, b, batch, sa, sb }))
    }

    /// y[b] = x[b * s]: the first sequence position of each batch element
    /// (the ViT CLS readout).
    pub fn seq_first(&mut self, x: Var, batch: usize, s: usize) -> Result<Var> {
        rules::seq_first(&self.value(x).shape, batch, s)
            .with_context(|| self.ctx("seq_first"))?;
        let xv = self.value(x);
        let d = xv.shape[1];
        let xvv = xv.f32s();
        // alloc_scratch: every row is fully overwritten below
        let mut out = arena::alloc_scratch(batch * d);
        for b in 0..batch {
            out[b * d..(b + 1) * d].copy_from_slice(&xvv[b * s * d..(b * s + 1) * d]);
        }
        let t = Tensor::from_f32(&[batch, d], out);
        Ok(self.push(t, Op::SeqFirst { x, batch, s }))
    }

    /// y[b] = mean of the s sequence rows of batch element b (probe pooling).
    pub fn seq_mean(&mut self, x: Var, batch: usize, s: usize) -> Result<Var> {
        rules::seq_mean(&self.value(x).shape, batch, s)
            .with_context(|| self.ctx("seq_mean"))?;
        let xv = self.value(x);
        let d = xv.shape[1];
        let xvv = xv.f32s();
        let inv = 1.0 / s as f32;
        let mut out = arena::alloc_zeroed(batch * d);
        for b in 0..batch {
            let orow = &mut out[b * d..(b + 1) * d];
            for r in 0..s {
                let xrow = &xvv[(b * s + r) * d..(b * s + r + 1) * d];
                for (o, &xx) in orow.iter_mut().zip(xrow) {
                    *o += xx * inv;
                }
            }
        }
        let t = Tensor::from_f32(&[batch, d], out);
        Ok(self.push(t, Op::SeqMean { x, batch, s }))
    }

    /// Scalar masked mean cross-entropy (labels < 0 ignored). Label count
    /// and range are validated before the kernel runs.
    pub fn masked_xent(&mut self, logits: Var, labels: Vec<i32>) -> Result<Var> {
        rules::masked_xent(&self.value(logits).shape, labels.len())
            .with_context(|| self.ctx("masked_xent"))?;
        let cols = self.value(logits).shape[1];
        for &l in &labels {
            if l >= 0 && l as usize >= cols {
                return Err(Error::msg(format!("label {l} outside vocab {cols}")))
                    .with_context(|| self.ctx("masked_xent"));
            }
        }
        let (loss, count) = ops::masked_xent_fwd(self.value(logits), &labels);
        Ok(self.push(Tensor::scalar_f32(loss), Op::MaskedXent { logits, labels, count }))
    }

    /// Scalar masked mean cross-entropy of the LM/classifier head
    /// `x @ w^T (+ b)` against per-row labels (labels < 0 ignored). With
    /// [`ops::fused_xent_enabled`] (the default) this is **one streaming
    /// node**: forward and backward run the vocab-tiled online-softmax
    /// kernels ([`ops::lm_head_xent_fwd`] / [`ops::lm_head_xent_bwd`]) and
    /// the `(rows, vocab)` logits are never materialized in either
    /// direction; `w`'s gradient accumulates into its leaf exactly like a
    /// [`Tape::linear_bias`] weight's, so a tied `emb_tok` head sums its
    /// gather and head contributions as before. With the knob off it lowers
    /// to the unfused linear_bias + masked_xent node chain for A/B runs.
    pub fn lm_head_xent(
        &mut self,
        x: Var,
        w: Var,
        b: Option<Var>,
        labels: Vec<i32>,
    ) -> Result<Var> {
        if !ops::fused_xent_enabled() {
            let logits = match b {
                Some(bv) => self.linear_bias(x, w, bv)?,
                None => self.linear(x, w)?,
            };
            return self.masked_xent(logits, labels);
        }
        let bshape = b.map(|bv| self.value(bv).shape.clone());
        rules::lm_head_xent(
            &self.value(x).shape,
            &self.value(w).shape,
            bshape.as_deref(),
            labels.len(),
        )
        .with_context(|| self.ctx("lm_head_xent"))?;
        let vocab = self.value(w).shape[0];
        for &l in &labels {
            if l >= 0 && l as usize >= vocab {
                return Err(Error::msg(format!("label {l} outside vocab {vocab}")))
                    .with_context(|| self.ctx("lm_head_xent"));
            }
        }
        let bias = b.map(|bv| self.value(bv));
        let (loss, count, stats) =
            ops::lm_head_xent_fwd(self.value(x), self.value(w), bias, &labels);
        Ok(self.push(Tensor::scalar_f32(loss), Op::LmHeadXent { x, w, b, labels, count, stats }))
    }

    /// Reverse sweep from the scalar `root`. Returns one gradient slot per
    /// node (None for nodes the root does not depend on); leaf slots hold
    /// the parameter gradients. Intermediate gradients are recycled into
    /// the arena as soon as their last consumer has run.
    pub fn backward(&self, root: Var) -> Vec<Option<Tensor>> {
        assert_eq!(self.value(root).numel(), 1, "backward root must be scalar");
        let mut grads: Vec<Option<Tensor>> = (0..self.nodes.len()).map(|_| None).collect();
        grads[root.0] = Some(Tensor::scalar_f32(1.0));
        for i in (0..=root.0).rev() {
            let Some(gout) = grads[i].take() else { continue };
            // arms that fully consume `gout` return None; the rest hand it
            // back for recycling
            let leftover: Option<Tensor> = match &self.nodes[i].op {
                Op::Leaf => {
                    grads[i] = Some(gout);
                    None
                }
                Op::Linear { x, w, b, act, pre } => {
                    let dy = match act {
                        Act::Gelu => {
                            let z = pre.as_ref().expect("fused GELU saves its pre-activation");
                            let d = ops::gelu_bwd(z, &gout);
                            arena::recycle(gout);
                            d
                        }
                        Act::None => gout,
                    };
                    if let Some(bv) = b {
                        let db = Tensor::from_f32(&self.value(*bv).shape, col_sums(&dy));
                        acc(&mut grads[bv.0], db);
                    }
                    let dx = ops::matmul(&dy, self.value(*w));
                    let dyt = ops::transpose(&dy);
                    let dw = ops::matmul(&dyt, self.value(*x));
                    arena::recycle(dyt);
                    arena::recycle(dy);
                    acc(&mut grads[x.0], dx);
                    acc(&mut grads[w.0], dw);
                    None
                }
                Op::AddRow { x, b } => {
                    let db = Tensor::from_f32(&self.value(*b).shape, col_sums(&gout));
                    acc(&mut grads[b.0], db);
                    acc(&mut grads[x.0], gout);
                    None
                }
                Op::Add { a, b } => {
                    let ga = Tensor::from_f32(&gout.shape, arena::alloc_copy(gout.f32s()));
                    acc(&mut grads[a.0], ga);
                    acc(&mut grads[b.0], gout);
                    None
                }
                Op::AddTiled { x, t, reps } => {
                    let tshape = self.value(*t).shape.clone();
                    let block = tshape[0] * tshape[1];
                    let mut dt = arena::alloc_zeroed(block);
                    for rep in 0..*reps {
                        let src = &gout.f32s()[rep * block..(rep + 1) * block];
                        for (a, &v) in dt.iter_mut().zip(src) {
                            *a += v;
                        }
                    }
                    acc(&mut grads[t.0], Tensor::from_f32(&tshape, dt));
                    acc(&mut grads[x.0], gout);
                    None
                }
                Op::MulRow { x, v } => {
                    let (xv, vv) = (self.value(*x), self.value(*v));
                    let d = xv.shape[1];
                    let mut dv = arena::alloc_zeroed(d);
                    let rows = gout.f32s().chunks_exact(d).zip(xv.f32s().chunks_exact(d));
                    for (grow, xrow) in rows {
                        for ((a, &gg), &xx) in dv.iter_mut().zip(grow).zip(xrow) {
                            *a += gg * xx;
                        }
                    }
                    // reuse gout's buffer as dx = gout * v (row-broadcast)
                    let mut dx = gout;
                    for row in dx.f32s_mut().chunks_exact_mut(d) {
                        for (o, &m) in row.iter_mut().zip(vv.f32s()) {
                            *o *= m;
                        }
                    }
                    acc(&mut grads[x.0], dx);
                    acc(&mut grads[v.0], Tensor::from_f32(&vv.shape, dv));
                    None
                }
                Op::Gelu { x } => {
                    let dx = ops::gelu_bwd(self.value(*x), &gout);
                    acc(&mut grads[x.0], dx);
                    Some(gout)
                }
                Op::LayerNorm { x, g, b, stats } => {
                    let (dx, dg, db) =
                        ops::layernorm_bwd(self.value(*x), self.value(*g), stats, &gout);
                    acc(&mut grads[x.0], dx);
                    acc(&mut grads[g.0], dg);
                    acc(&mut grads[b.0], db);
                    Some(gout)
                }
                Op::Attention { q, k, v, sh, probs } => {
                    let (dq, dk, dv) = ops::attention_bwd(
                        self.value(*q),
                        self.value(*k),
                        self.value(*v),
                        probs,
                        &gout,
                        sh,
                    );
                    acc(&mut grads[q.0], dq);
                    acc(&mut grads[k.0], dk);
                    acc(&mut grads[v.0], dv);
                    Some(gout)
                }
                Op::Gather { emb, ids } => {
                    let eshape = self.value(*emb).shape.clone();
                    let d = eshape[1];
                    let mut de = arena::alloc_zeroed(eshape[0] * d);
                    for (i_row, &id) in ids.iter().enumerate() {
                        let dst = &mut de[id as usize * d..(id as usize + 1) * d];
                        let src = &gout.f32s()[i_row * d..(i_row + 1) * d];
                        for (a, &v) in dst.iter_mut().zip(src) {
                            *a += v;
                        }
                    }
                    acc(&mut grads[emb.0], Tensor::from_f32(&eshape, de));
                    Some(gout)
                }
                Op::BroadcastRow { v, reps: _ } => {
                    let dv = Tensor::from_f32(&self.value(*v).shape, col_sums(&gout));
                    acc(&mut grads[v.0], dv);
                    Some(gout)
                }
                Op::ConcatSeq { a, b, batch, sa, sb } => {
                    let d = gout.shape[1];
                    let gv = gout.f32s();
                    let mut da = arena::alloc_zeroed(batch * sa * d);
                    let mut db = arena::alloc_zeroed(batch * sb * d);
                    for bi in 0..*batch {
                        let base = bi * (sa + sb) * d;
                        da[bi * sa * d..(bi + 1) * sa * d]
                            .copy_from_slice(&gv[base..base + sa * d]);
                        db[bi * sb * d..(bi + 1) * sb * d]
                            .copy_from_slice(&gv[base + sa * d..base + (sa + sb) * d]);
                    }
                    acc(&mut grads[a.0], Tensor::from_f32(&[batch * sa, d], da));
                    acc(&mut grads[b.0], Tensor::from_f32(&[batch * sb, d], db));
                    Some(gout)
                }
                Op::SeqFirst { x, batch, s } => {
                    let d = gout.shape[1];
                    let mut dx = arena::alloc_zeroed(batch * s * d);
                    for bi in 0..*batch {
                        dx[bi * s * d..bi * s * d + d]
                            .copy_from_slice(&gout.f32s()[bi * d..(bi + 1) * d]);
                    }
                    acc(&mut grads[x.0], Tensor::from_f32(&[batch * s, d], dx));
                    Some(gout)
                }
                Op::SeqMean { x, batch, s } => {
                    let d = gout.shape[1];
                    let inv = 1.0 / *s as f32;
                    let mut dx = arena::alloc_zeroed(batch * s * d);
                    for bi in 0..*batch {
                        let grow = &gout.f32s()[bi * d..(bi + 1) * d];
                        for r in 0..*s {
                            let dst = &mut dx[(bi * s + r) * d..(bi * s + r + 1) * d];
                            for (a, &v) in dst.iter_mut().zip(grow) {
                                *a = v * inv;
                            }
                        }
                    }
                    acc(&mut grads[x.0], Tensor::from_f32(&[batch * s, d], dx));
                    Some(gout)
                }
                Op::MaskedXent { logits, labels, count } => {
                    let dl =
                        ops::masked_xent_bwd(self.value(*logits), labels, *count, gout.item());
                    acc(&mut grads[logits.0], dl);
                    Some(gout)
                }
                Op::LmHeadXent { x, w, b, labels, count, stats } => {
                    let bias = b.map(|bv| self.value(bv));
                    let (dx, dw, db) = ops::lm_head_xent_bwd(
                        self.value(*x),
                        self.value(*w),
                        bias,
                        labels,
                        stats,
                        *count,
                        gout.item(),
                    );
                    acc(&mut grads[x.0], dx);
                    acc(&mut grads[w.0], dw);
                    if let (Some(bv), Some(dbt)) = (b, db) {
                        acc(&mut grads[bv.0], dbt);
                    }
                    Some(gout)
                }
            };
            if let Some(g) = leftover {
                arena::recycle(g);
            }
        }
        grads
    }
}

impl Drop for Tape<'_> {
    /// Recycle every owned node value and all saved backward state into
    /// the thread-local arena (borrowed leaves belong to the caller).
    fn drop(&mut self) {
        for node in self.nodes.drain(..) {
            if let Value::Owned(t) = node.value {
                arena::recycle(t);
            }
            match node.op {
                Op::Attention { probs, .. } => arena::recycle(probs),
                Op::Linear { pre: Some(z), .. } => arena::recycle(z),
                Op::LayerNorm { stats, .. } => arena::recycle_buf(stats),
                Op::LmHeadXent { stats, .. } => arena::recycle_buf(stats),
                _ => {}
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::store::Store;
    use crate::util::rng::Rng;

    fn rand_t(shape: &[usize], rng: &mut Rng) -> Tensor {
        let n = crate::tensor::numel(shape);
        Tensor::from_f32(shape, (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect())
    }

    /// Evaluate the composite graph used by the FD test below on explicit
    /// leaf tensors; returns the scalar loss.
    fn graph_loss(leaves: &Store) -> f32 {
        let mut tape = Tape::new();
        let emb = tape.leaf(leaves.expect("emb").clone());
        let t = tape.leaf(leaves.expect("t").clone());
        let v = tape.leaf(leaves.expect("v").clone());
        let b = tape.leaf(leaves.expect("b").clone());
        let w = tape.leaf(leaves.expect("w").clone());
        let g1 = tape.gather(emb, vec![0, 2, 4, 1]).unwrap();
        let g2 = tape.add_tiled(g1, t, 2).unwrap();
        let g3 = tape.mul_row(g2, v).unwrap();
        let g4 = tape.add_row(g3, b).unwrap();
        let lin = tape.linear(g4, w).unwrap();
        let loss = tape.masked_xent(lin, vec![1, -1, 0, 3]).unwrap();
        tape.value(loss).item()
    }

    #[test]
    fn composite_graph_fd_gradients() {
        let mut rng = Rng::new(17);
        let mut leaves = Store::new();
        leaves.insert("emb", rand_t(&[5, 3], &mut rng));
        leaves.insert("t", rand_t(&[2, 3], &mut rng));
        leaves.insert("v", rand_t(&[3], &mut rng));
        leaves.insert("b", rand_t(&[3], &mut rng));
        leaves.insert("w", rand_t(&[4, 3], &mut rng));

        // analytic gradients
        let mut tape = Tape::new();
        let names = ["emb", "t", "v", "b", "w"];
        let vars: Vec<Var> = names.iter().map(|n| tape.leaf(leaves.expect(n).clone())).collect();
        let g1 = tape.gather(vars[0], vec![0, 2, 4, 1]).unwrap();
        let g2 = tape.add_tiled(g1, vars[1], 2).unwrap();
        let g3 = tape.mul_row(g2, vars[2]).unwrap();
        let g4 = tape.add_row(g3, vars[3]).unwrap();
        let lin = tape.linear(g4, vars[4]).unwrap();
        let loss = tape.masked_xent(lin, vec![1, -1, 0, 3]).unwrap();
        let grads = tape.backward(loss);

        let eps = 1e-2f32;
        for (name, var) in names.iter().zip(&vars) {
            let g = grads[var.index()].as_ref().expect("leaf gradient");
            for i in 0..g.numel() {
                let mut plus = leaves.clone();
                plus.get_mut(name).unwrap().f32s_mut()[i] += eps;
                let mut minus = leaves.clone();
                minus.get_mut(name).unwrap().f32s_mut()[i] -= eps;
                let fd = (graph_loss(&plus) - graph_loss(&minus)) / (2.0 * eps);
                let a = g.f32s()[i];
                let rel = (a - fd).abs() / a.abs().max(fd.abs()).max(1.0);
                assert!(rel < 1e-3, "{name}[{i}]: analytic {a} vs fd {fd}");
            }
        }
    }

    #[test]
    fn shared_leaf_accumulates_both_uses() {
        // loss = xent(x @ x^T): the leaf feeds the op twice; its gradient
        // must be the sum of both path contributions (FD-checked).
        let mut rng = Rng::new(5);
        let x0 = rand_t(&[3, 3], &mut rng);
        let f = |x: &Tensor| {
            let mut tape = Tape::new();
            let x = tape.leaf(x.clone());
            let y = tape.linear(x, x).unwrap();
            let loss = tape.masked_xent(y, vec![0, 2, 1]).unwrap();
            (tape, x, loss)
        };
        let (tape, xv, loss) = f(&x0);
        let grads = tape.backward(loss);
        let g = grads[xv.index()].as_ref().unwrap();
        let eps = 1e-2f32;
        for i in 0..x0.numel() {
            let mut p = x0.clone();
            p.f32s_mut()[i] += eps;
            let mut m = x0.clone();
            m.f32s_mut()[i] -= eps;
            let lp = {
                let (t, _, l) = f(&p);
                t.value(l).item()
            };
            let lm = {
                let (t, _, l) = f(&m);
                t.value(l).item()
            };
            let fd = (lp - lm) / (2.0 * eps);
            let a = g.f32s()[i];
            let rel = (a - fd).abs() / a.abs().max(fd.abs()).max(1.0);
            assert!(rel < 1e-3, "x[{i}]: analytic {a} vs fd {fd}");
        }
    }

    #[test]
    fn seq_ops_roundtrip_values_and_gradients() {
        let mut tape = Tape::new();
        let cls = tape.leaf(Tensor::from_f32(&[2], vec![1.0, 2.0]));
        let patches = tape.leaf(Tensor::from_f32(&[4, 2], vec![0.1; 8]));
        let bc = tape.broadcast_row(cls, 2); // (2 batches, 1 row each)
        let cat = tape.concat_seq(bc, patches, 2, 1, 2).unwrap(); // (2*(1+2), 2)
        assert_eq!(tape.value(cat).shape, vec![6, 2]);
        assert_eq!(tape.value(cat).at2(0, 1), 2.0); // cls row leads each block
        assert_eq!(tape.value(cat).at2(3, 0), 1.0);
        let first = tape.seq_first(cat, 2, 3).unwrap();
        assert_eq!(tape.value(first).f32s(), &[1.0, 2.0, 1.0, 2.0]);
        let mean = tape.seq_mean(cat, 2, 3).unwrap();
        assert!((tape.value(mean).at2(0, 0) - (1.0 + 0.1 + 0.1) / 3.0).abs() < 1e-6);
        // dummy scalar through a linear head for the backward sweep
        let w = tape.leaf(Tensor::from_f32(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]));
        let lin = tape.linear(mean, w).unwrap();
        let loss = tape.masked_xent(lin, vec![0, 1]).unwrap();
        let grads = tape.backward(loss);
        assert!(grads[cls.index()].is_some(), "cls leaf must receive gradient");
        assert!(grads[patches.index()].is_some());
    }

    #[test]
    fn param_leaves_borrow_without_copying() {
        let w = Tensor::from_f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let mut tape = Tape::new();
        let wv = tape.param(&w);
        // the tape's view *is* the caller's tensor — same allocation
        assert!(std::ptr::eq(tape.value(wv), &w), "param leaf must borrow, not copy");
        // and borrowed leaves still get owned gradients
        let x = tape.leaf(Tensor::from_f32(&[1, 3], vec![1.0, 0.0, -1.0]));
        let y = tape.linear(x, wv).unwrap();
        let loss = tape.masked_xent(y, vec![1]).unwrap();
        let grads = tape.backward(loss);
        let gw = grads[wv.index()].as_ref().expect("borrowed leaf gradient");
        assert_eq!(gw.shape, w.shape);
        assert!(!std::ptr::eq(gw, &w));
    }

    /// Fused linear_bias_gelu against the unfused chain: same value to
    /// ≤1e-5 relative, and the fused backward passes the FD check.
    #[test]
    fn fused_linear_matches_unfused_and_fd() {
        let mut rng = Rng::new(23);
        let x0 = rand_t(&[4, 6], &mut rng);
        let w0 = rand_t(&[5, 6], &mut rng);
        let b0 = rand_t(&[5], &mut rng);
        let labels = vec![0, 3, -1, 4];
        let run = |fused: bool, xs: &Tensor, ws: &Tensor, bs: &Tensor| {
            ops::set_fused_override(Some(fused));
            let mut tape = Tape::new();
            let x = tape.leaf(xs.clone());
            let w = tape.param(ws);
            let b = tape.param(bs);
            let y = tape.linear_bias_gelu(x, w, b).unwrap();
            let yv = tape.value(y).clone();
            let loss = tape.masked_xent(y, labels.clone()).unwrap();
            let l = tape.value(loss).item();
            let grads = tape.backward(loss);
            let gw = grads[w.index()].as_ref().unwrap().clone();
            let gb = grads[b.index()].as_ref().unwrap().clone();
            ops::set_fused_override(None);
            (yv, l, gw, gb)
        };
        let (yf, lf, gwf, gbf) = run(true, &x0, &w0, &b0);
        let (yu, lu, gwu, gbu) = run(false, &x0, &w0, &b0);
        for (a, b) in yf.f32s().iter().zip(yu.f32s()) {
            let rel = (a - b).abs() / a.abs().max(b.abs()).max(1.0);
            assert!(rel <= 1e-5, "fused {a} vs unfused {b}");
        }
        assert!((lf - lu).abs() <= 1e-5 * lf.abs().max(1.0), "{lf} vs {lu}");
        for (a, b) in gwf.f32s().iter().zip(gwu.f32s()) {
            assert!((a - b).abs() <= 1e-5 * a.abs().max(b.abs()).max(1.0), "{a} vs {b}");
        }
        for (a, b) in gbf.f32s().iter().zip(gbu.f32s()) {
            assert!((a - b).abs() <= 1e-5 * a.abs().max(b.abs()).max(1.0), "{a} vs {b}");
        }
        // FD on the fused backward (weight + bias entries)
        let eps = 1e-2f32;
        for i in 0..w0.numel() {
            let mut p = w0.clone();
            p.f32s_mut()[i] += eps;
            let mut m = w0.clone();
            m.f32s_mut()[i] -= eps;
            let fd = (run(true, &x0, &p, &b0).1 - run(true, &x0, &m, &b0).1) / (2.0 * eps);
            let a = gwf.f32s()[i];
            let rel = (a - fd).abs() / a.abs().max(fd.abs()).max(1.0);
            assert!(rel < 1e-3, "dw[{i}]: analytic {a} vs fd {fd}");
        }
        for i in 0..b0.numel() {
            let mut p = b0.clone();
            p.f32s_mut()[i] += eps;
            let mut m = b0.clone();
            m.f32s_mut()[i] -= eps;
            let fd = (run(true, &x0, &w0, &p).1 - run(true, &x0, &w0, &m).1) / (2.0 * eps);
            let a = gbf.f32s()[i];
            let rel = (a - fd).abs() / a.abs().max(fd.abs()).max(1.0);
            assert!(rel < 1e-3, "db[{i}]: analytic {a} vs fd {fd}");
        }
    }

    /// The streaming fused LM-head node against the unfused
    /// linear_bias + masked_xent chain: same loss and same leaf gradients
    /// to ≤1e-5 relative, and the fused backward passes the FD check —
    /// including the tied-weight case where the head weight leaf is also
    /// consumed by a gather (the `emb_tok` tying), whose gradient must be
    /// the sum of both contributions.
    #[test]
    fn fused_lm_head_matches_unfused_and_fd() {
        let mut rng = Rng::new(29);
        let emb0 = rand_t(&[9, 6], &mut rng); // vocab 9, dim 6
        let bias0 = rand_t(&[9], &mut rng);
        let ids = vec![0i32, 4, 8, 2, 5, 1];
        let labels = vec![3i32, -1, 0, 8, -1, 6];
        let run = |fused: bool, emb: &Tensor, bias: &Tensor| {
            ops::set_fused_xent_override(Some(fused));
            let mut tape = Tape::new();
            let e = tape.param(emb);
            let bb = tape.param(bias);
            let x = tape.gather(e, ids.clone()).unwrap(); // ties emb into the input path
            let loss = tape.lm_head_xent(x, e, Some(bb), labels.clone()).unwrap();
            let l = tape.value(loss).item();
            let grads = tape.backward(loss);
            let ge = grads[e.index()].as_ref().unwrap().clone();
            let gb = grads[bb.index()].as_ref().unwrap().clone();
            ops::set_fused_xent_override(None);
            (l, ge, gb)
        };
        let (lf, gef, gbf) = run(true, &emb0, &bias0);
        let (lu, geu, gbu) = run(false, &emb0, &bias0);
        assert!((lf - lu).abs() <= 1e-5 * lf.abs().max(1.0), "{lf} vs {lu}");
        for (a, b) in gef.f32s().iter().zip(geu.f32s()) {
            let rel = (a - b).abs() / a.abs().max(b.abs()).max(1.0);
            assert!(rel <= 1e-5, "tied emb grad: fused {a} vs unfused {b}");
        }
        for (a, b) in gbf.f32s().iter().zip(gbu.f32s()) {
            let rel = (a - b).abs() / a.abs().max(b.abs()).max(1.0);
            assert!(rel <= 1e-5, "bias grad: fused {a} vs unfused {b}");
        }
        // FD through the fused node (tied gather + head contributions)
        let eps = 1e-2f32;
        for i in 0..emb0.numel() {
            let mut p = emb0.clone();
            p.f32s_mut()[i] += eps;
            let mut m = emb0.clone();
            m.f32s_mut()[i] -= eps;
            let fd = (run(true, &p, &bias0).0 - run(true, &m, &bias0).0) / (2.0 * eps);
            let a = gef.f32s()[i];
            let rel = (a - fd).abs() / a.abs().max(fd.abs()).max(1.0);
            assert!(rel < 1e-3, "demb[{i}]: analytic {a} vs fd {fd}");
        }
        for i in 0..bias0.numel() {
            let mut p = bias0.clone();
            p.f32s_mut()[i] += eps;
            let mut m = bias0.clone();
            m.f32s_mut()[i] -= eps;
            let fd = (run(true, &emb0, &p).0 - run(true, &emb0, &m).0) / (2.0 * eps);
            let a = gbf.f32s()[i];
            let rel = (a - fd).abs() / a.abs().max(fd.abs()).max(1.0);
            assert!(rel < 1e-3, "dbias[{i}]: analytic {a} vs fd {fd}");
        }
    }

    #[test]
    fn lm_head_xent_unfused_lowering_without_bias() {
        // the knob-off route with b = None must lower to plain linear +
        // masked_xent and still gradient both leaves
        ops::set_fused_xent_override(Some(false));
        let mut rng = Rng::new(31);
        let x0 = rand_t(&[3, 4], &mut rng);
        let w0 = rand_t(&[5, 4], &mut rng);
        let mut tape = Tape::new();
        let x = tape.leaf(x0.clone());
        let w = tape.param(&w0);
        let loss = tape.lm_head_xent(x, w, None, vec![1, -1, 4]).unwrap();
        // leaf + param + Linear + MaskedXent (the fused route would be 3)
        assert_eq!(tape.len(), 4, "unfused route must append the node chain");
        let grads = tape.backward(loss);
        assert!(grads[w.index()].is_some());
        assert!(grads[x.index()].is_some());
        ops::set_fused_xent_override(None);
    }

    /// Malformed graphs surface as typed errors naming the offending node
    /// — never as kernel panics — and a failed op appends nothing.
    #[test]
    fn malformed_ops_return_typed_errors_naming_the_node() {
        let mut tape = Tape::new();
        let x = tape.leaf(Tensor::from_f32(&[2, 3], vec![0.0; 6]));
        let b = tape.leaf(Tensor::from_f32(&[4], vec![0.0; 4]));
        let err = tape.add_row(x, b).unwrap_err().to_string();
        assert!(err.contains("add_row") && err.contains("bias"), "{err}");
        assert_eq!(tape.len(), 2, "a rejected op must not append a node");
        let emb = tape.leaf(Tensor::from_f32(&[3, 2], vec![0.0; 6]));
        let err = tape.gather(emb, vec![0, 3]).unwrap_err().to_string();
        assert!(err.contains("gather id 3 outside [0, 3)"), "{err}");
        let w = tape.leaf(Tensor::from_f32(&[5, 3], vec![0.0; 15]));
        let y = tape.linear(x, w).unwrap();
        let err = tape.masked_xent(y, vec![0, 9]).unwrap_err().to_string();
        assert!(err.contains("label 9 outside vocab 5"), "{err}");
        ops::set_fused_xent_override(Some(true));
        let err = tape.lm_head_xent(x, w, None, vec![0]).unwrap_err().to_string();
        assert!(err.contains("lm_head_xent") && err.contains("one label per"), "{err}");
        ops::set_fused_xent_override(None);
        let q = tape.leaf(Tensor::from_f32(&[4, 6], vec![0.0; 24]));
        let sh = AttnShape { batch: 2, heads: 4, s_q: 2, s_k: 2, causal: false };
        let err = tape.attention(q, q, q, sh).unwrap_err().to_string();
        assert!(err.contains("attention") && err.contains("not divisible"), "{err}");
    }
}
