//! Minimal reverse-mode autodiff over [`Tensor`]s — the substrate of the
//! native model engine.
//!
//! A [`Tape`] is an append-only arena of nodes; every op evaluates eagerly
//! (so [`Tape::value`] is always available) and records what it needs for
//! the reverse sweep (layernorm statistics, attention probabilities).
//! [`Tape::backward`] walks the arena once in reverse, accumulating
//! gradients into every node the scalar root depends on — shared leaves
//! (e.g. the tied `emb_tok` used by both the embedding gather and the LM
//! head) accumulate from all of their uses automatically.
//!
//! Activations are kept 2-D throughout: a transformer stream is flattened
//! to `(batch * seq, dim)` and the attention op carries the
//! (batch, heads, s_q, s_k) layout in its [`AttnShape`].

use crate::tensor::ops::{self, AttnShape};
use crate::tensor::Tensor;

/// Handle to a tape node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Var(usize);

impl Var {
    /// Arena index (for looking up this node's gradient after `backward`).
    pub fn index(self) -> usize {
        self.0
    }
}

enum Op {
    Leaf,
    /// y = x @ w^T — dense layer on (out, in)-stored weights, no bias.
    Linear { x: Var, w: Var },
    /// y = x + b with b broadcast over rows.
    AddRow { x: Var, b: Var },
    /// y = a + b, same shape.
    Add { a: Var, b: Var },
    /// y = x + tile(t, reps): t (s, d) added to each of `reps` row blocks.
    AddTiled { x: Var, t: Var, reps: usize },
    /// y = x * v with v broadcast over rows (CaiT LayerScale).
    MulRow { x: Var, v: Var },
    Gelu { x: Var },
    LayerNorm { x: Var, g: Var, b: Var, stats: Vec<f32> },
    Attention { q: Var, k: Var, v: Var, sh: AttnShape, probs: Tensor },
    /// y[i] = emb[ids[i]] — embedding row gather.
    Gather { emb: Var, ids: Vec<i32> },
    /// y = v (a d-vector) broadcast to (reps, d).
    BroadcastRow { v: Var, reps: usize },
    /// Per batch element: concat sa rows of `a` with sb rows of `b`.
    ConcatSeq { a: Var, b: Var, batch: usize, sa: usize, sb: usize },
    /// y[b] = x[b * s] — the first sequence position of each batch element.
    SeqFirst { x: Var, batch: usize, s: usize },
    /// y[b] = mean over the s sequence rows of batch element b.
    SeqMean { x: Var, batch: usize, s: usize },
    /// Scalar masked mean cross-entropy over the rows of `logits`.
    MaskedXent { logits: Var, labels: Vec<i32>, count: f32 },
}

struct Node {
    value: Tensor,
    op: Op,
}

/// The autodiff arena. See the module docs.
#[derive(Default)]
pub struct Tape {
    nodes: Vec<Node>,
}

/// Accumulate `t` into an optional gradient slot.
fn acc(slot: &mut Option<Tensor>, t: Tensor) {
    match slot {
        Some(a) => {
            debug_assert_eq!(a.shape, t.shape, "gradient shape mismatch");
            for (x, y) in a.f32s_mut().iter_mut().zip(t.f32s()) {
                *x += y;
            }
        }
        None => *slot = Some(t),
    }
}

/// Column sums of a 2-D gradient (the broadcast-bias backward).
fn col_sums(g: &Tensor) -> Vec<f32> {
    let d = g.shape[1];
    let mut out = vec![0.0f32; d];
    for row in g.f32s().chunks_exact(d) {
        for (a, &v) in out.iter_mut().zip(row) {
            *a += v;
        }
    }
    out
}

impl Tape {
    pub fn new() -> Tape {
        Tape::default()
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// The (eagerly computed) value of a node.
    pub fn value(&self, v: Var) -> &Tensor {
        &self.nodes[v.0].value
    }

    fn push(&mut self, value: Tensor, op: Op) -> Var {
        self.nodes.push(Node { value, op });
        Var(self.nodes.len() - 1)
    }

    /// A constant or parameter input node.
    pub fn leaf(&mut self, t: Tensor) -> Var {
        self.push(t, Op::Leaf)
    }

    /// y = x @ w^T for x (n, in) and w (out, in) — the y = W x convention
    /// every stored projection uses.
    pub fn linear(&mut self, x: Var, w: Var) -> Var {
        let y = ops::matmul_nt(self.value(x), self.value(w));
        self.push(y, Op::Linear { x, w })
    }

    /// y = x + b with the bias broadcast over rows.
    pub fn add_row(&mut self, x: Var, b: Var) -> Var {
        let (xv, bv) = (self.value(x), self.value(b));
        let d = xv.shape[1];
        assert_eq!(bv.numel(), d, "add_row bias dim");
        let mut out = xv.clone();
        for row in out.f32s_mut().chunks_exact_mut(d) {
            for (o, &bb) in row.iter_mut().zip(bv.f32s()) {
                *o += bb;
            }
        }
        self.push(out, Op::AddRow { x, b })
    }

    /// y = a + b (same shape; the residual connection).
    pub fn add(&mut self, a: Var, b: Var) -> Var {
        let out = ops::axpy(self.value(a), 1.0, self.value(b));
        self.push(out, Op::Add { a, b })
    }

    /// y = x + tile(t, reps): adds t (s, d) to each of `reps` consecutive
    /// s-row blocks of x (the positional-embedding broadcast over batch).
    pub fn add_tiled(&mut self, x: Var, t: Var, reps: usize) -> Var {
        let (xv, tv) = (self.value(x), self.value(t));
        let (s, d) = (tv.shape[0], tv.shape[1]);
        assert_eq!(xv.shape, vec![reps * s, d], "add_tiled shapes");
        let mut out = xv.clone();
        let tvv = tv.f32s();
        for block in out.f32s_mut().chunks_exact_mut(s * d) {
            for (o, &tt) in block.iter_mut().zip(tvv) {
                *o += tt;
            }
        }
        self.push(out, Op::AddTiled { x, t, reps })
    }

    /// y = x * v with v broadcast over rows (LayerScale).
    pub fn mul_row(&mut self, x: Var, v: Var) -> Var {
        let (xv, vv) = (self.value(x), self.value(v));
        let d = xv.shape[1];
        assert_eq!(vv.numel(), d, "mul_row vector dim");
        let mut out = xv.clone();
        for row in out.f32s_mut().chunks_exact_mut(d) {
            for (o, &m) in row.iter_mut().zip(vv.f32s()) {
                *o *= m;
            }
        }
        self.push(out, Op::MulRow { x, v })
    }

    pub fn gelu(&mut self, x: Var) -> Var {
        let y = ops::gelu_fwd(self.value(x));
        self.push(y, Op::Gelu { x })
    }

    pub fn layernorm(&mut self, x: Var, g: Var, b: Var) -> Var {
        let (y, stats) = ops::layernorm_fwd(self.value(x), self.value(g), self.value(b));
        self.push(y, Op::LayerNorm { x, g, b, stats })
    }

    /// Multi-head softmax attention; see [`ops::attention_fwd`].
    pub fn attention(&mut self, q: Var, k: Var, v: Var, sh: AttnShape) -> Var {
        let (out, probs) = ops::attention_fwd(self.value(q), self.value(k), self.value(v), &sh);
        self.push(out, Op::Attention { q, k, v, sh, probs })
    }

    /// y[i] = emb[ids[i]] — token/row embedding lookup.
    pub fn gather(&mut self, emb: Var, ids: Vec<i32>) -> Var {
        let ev = self.value(emb);
        let (rows, d) = (ev.shape[0], ev.shape[1]);
        let evv = ev.f32s();
        let mut out = Vec::with_capacity(ids.len() * d);
        for &id in &ids {
            assert!(id >= 0 && (id as usize) < rows, "gather id {id} outside [0, {rows})");
            let r = id as usize;
            out.extend_from_slice(&evv[r * d..(r + 1) * d]);
        }
        let t = Tensor::from_f32(&[ids.len(), d], out);
        self.push(t, Op::Gather { emb, ids })
    }

    /// y = v (a d-vector) broadcast to (reps, d) — the CLS token.
    pub fn broadcast_row(&mut self, v: Var, reps: usize) -> Var {
        let vv = self.value(v);
        let d = vv.numel();
        let mut out = Vec::with_capacity(reps * d);
        for _ in 0..reps {
            out.extend_from_slice(vv.f32s());
        }
        let t = Tensor::from_f32(&[reps, d], out);
        self.push(t, Op::BroadcastRow { v, reps })
    }

    /// Per batch element, concat sa rows of `a` with sb rows of `b` along
    /// the sequence axis (CLS-token prepend / class-attention key stream).
    pub fn concat_seq(&mut self, a: Var, b: Var, batch: usize, sa: usize, sb: usize) -> Var {
        let (av, bv) = (self.value(a), self.value(b));
        let d = av.shape[1];
        assert_eq!(av.shape, vec![batch * sa, d], "concat_seq a shape");
        assert_eq!(bv.shape, vec![batch * sb, d], "concat_seq b shape");
        let (avv, bvv) = (av.f32s(), bv.f32s());
        let mut out = Vec::with_capacity(batch * (sa + sb) * d);
        for bi in 0..batch {
            out.extend_from_slice(&avv[bi * sa * d..(bi + 1) * sa * d]);
            out.extend_from_slice(&bvv[bi * sb * d..(bi + 1) * sb * d]);
        }
        let t = Tensor::from_f32(&[batch * (sa + sb), d], out);
        self.push(t, Op::ConcatSeq { a, b, batch, sa, sb })
    }

    /// y[b] = x[b * s]: the first sequence position of each batch element
    /// (the ViT CLS readout).
    pub fn seq_first(&mut self, x: Var, batch: usize, s: usize) -> Var {
        let xv = self.value(x);
        let d = xv.shape[1];
        assert_eq!(xv.shape, vec![batch * s, d], "seq_first shape");
        let xvv = xv.f32s();
        let mut out = Vec::with_capacity(batch * d);
        for b in 0..batch {
            out.extend_from_slice(&xvv[b * s * d..(b * s + 1) * d]);
        }
        let t = Tensor::from_f32(&[batch, d], out);
        self.push(t, Op::SeqFirst { x, batch, s })
    }

    /// y[b] = mean of the s sequence rows of batch element b (probe pooling).
    pub fn seq_mean(&mut self, x: Var, batch: usize, s: usize) -> Var {
        let xv = self.value(x);
        let d = xv.shape[1];
        assert_eq!(xv.shape, vec![batch * s, d], "seq_mean shape");
        let xvv = xv.f32s();
        let inv = 1.0 / s as f32;
        let mut out = vec![0.0f32; batch * d];
        for b in 0..batch {
            let orow = &mut out[b * d..(b + 1) * d];
            for r in 0..s {
                let xrow = &xvv[(b * s + r) * d..(b * s + r + 1) * d];
                for (o, &xx) in orow.iter_mut().zip(xrow) {
                    *o += xx * inv;
                }
            }
        }
        let t = Tensor::from_f32(&[batch, d], out);
        self.push(t, Op::SeqMean { x, batch, s })
    }

    /// Scalar masked mean cross-entropy (labels < 0 ignored).
    pub fn masked_xent(&mut self, logits: Var, labels: Vec<i32>) -> Var {
        let (loss, count) = ops::masked_xent_fwd(self.value(logits), &labels);
        self.push(Tensor::scalar_f32(loss), Op::MaskedXent { logits, labels, count })
    }

    /// Reverse sweep from the scalar `root`. Returns one gradient slot per
    /// node (None for nodes the root does not depend on); leaf slots hold
    /// the parameter gradients.
    pub fn backward(&self, root: Var) -> Vec<Option<Tensor>> {
        assert_eq!(self.nodes[root.0].value.numel(), 1, "backward root must be scalar");
        let mut grads: Vec<Option<Tensor>> = (0..self.nodes.len()).map(|_| None).collect();
        grads[root.0] = Some(Tensor::scalar_f32(1.0));
        for i in (0..=root.0).rev() {
            let Some(gout) = grads[i].take() else { continue };
            match &self.nodes[i].op {
                Op::Leaf => {
                    grads[i] = Some(gout);
                }
                Op::Linear { x, w } => {
                    let dx = ops::matmul(&gout, self.value(*w));
                    let dw = ops::matmul(&ops::transpose(&gout), self.value(*x));
                    acc(&mut grads[x.0], dx);
                    acc(&mut grads[w.0], dw);
                }
                Op::AddRow { x, b } => {
                    let db = Tensor::from_f32(&self.value(*b).shape, col_sums(&gout));
                    acc(&mut grads[b.0], db);
                    acc(&mut grads[x.0], gout);
                }
                Op::Add { a, b } => {
                    acc(&mut grads[a.0], gout.clone());
                    acc(&mut grads[b.0], gout);
                }
                Op::AddTiled { x, t, reps } => {
                    let tshape = self.value(*t).shape.clone();
                    let block = tshape[0] * tshape[1];
                    let mut dt = vec![0.0f32; block];
                    for rep in 0..*reps {
                        let src = &gout.f32s()[rep * block..(rep + 1) * block];
                        for (a, &v) in dt.iter_mut().zip(src) {
                            *a += v;
                        }
                    }
                    acc(&mut grads[t.0], Tensor::from_f32(&tshape, dt));
                    acc(&mut grads[x.0], gout);
                }
                Op::MulRow { x, v } => {
                    let (xv, vv) = (self.value(*x), self.value(*v));
                    let d = xv.shape[1];
                    let mut dx = gout.clone();
                    for row in dx.f32s_mut().chunks_exact_mut(d) {
                        for (o, &m) in row.iter_mut().zip(vv.f32s()) {
                            *o *= m;
                        }
                    }
                    let mut dv = vec![0.0f32; d];
                    let rows = gout.f32s().chunks_exact(d).zip(xv.f32s().chunks_exact(d));
                    for (grow, xrow) in rows {
                        for ((a, &gg), &xx) in dv.iter_mut().zip(grow).zip(xrow) {
                            *a += gg * xx;
                        }
                    }
                    acc(&mut grads[x.0], dx);
                    acc(&mut grads[v.0], Tensor::from_f32(&vv.shape, dv));
                }
                Op::Gelu { x } => {
                    let dx = ops::gelu_bwd(self.value(*x), &gout);
                    acc(&mut grads[x.0], dx);
                }
                Op::LayerNorm { x, g, b, stats } => {
                    let (dx, dg, db) =
                        ops::layernorm_bwd(self.value(*x), self.value(*g), stats, &gout);
                    acc(&mut grads[x.0], dx);
                    acc(&mut grads[g.0], dg);
                    acc(&mut grads[b.0], db);
                }
                Op::Attention { q, k, v, sh, probs } => {
                    let (dq, dk, dv) = ops::attention_bwd(
                        self.value(*q),
                        self.value(*k),
                        self.value(*v),
                        probs,
                        &gout,
                        sh,
                    );
                    acc(&mut grads[q.0], dq);
                    acc(&mut grads[k.0], dk);
                    acc(&mut grads[v.0], dv);
                }
                Op::Gather { emb, ids } => {
                    let eshape = self.value(*emb).shape.clone();
                    let d = eshape[1];
                    let mut de = vec![0.0f32; eshape[0] * d];
                    for (i_row, &id) in ids.iter().enumerate() {
                        let dst = &mut de[id as usize * d..(id as usize + 1) * d];
                        let src = &gout.f32s()[i_row * d..(i_row + 1) * d];
                        for (a, &v) in dst.iter_mut().zip(src) {
                            *a += v;
                        }
                    }
                    acc(&mut grads[emb.0], Tensor::from_f32(&eshape, de));
                }
                Op::BroadcastRow { v, reps: _ } => {
                    let dv = Tensor::from_f32(&self.value(*v).shape, col_sums(&gout));
                    acc(&mut grads[v.0], dv);
                }
                Op::ConcatSeq { a, b, batch, sa, sb } => {
                    let d = gout.shape[1];
                    let gv = gout.f32s();
                    let mut da = vec![0.0f32; batch * sa * d];
                    let mut db = vec![0.0f32; batch * sb * d];
                    for bi in 0..*batch {
                        let base = bi * (sa + sb) * d;
                        da[bi * sa * d..(bi + 1) * sa * d]
                            .copy_from_slice(&gv[base..base + sa * d]);
                        db[bi * sb * d..(bi + 1) * sb * d]
                            .copy_from_slice(&gv[base + sa * d..base + (sa + sb) * d]);
                    }
                    acc(&mut grads[a.0], Tensor::from_f32(&[batch * sa, d], da));
                    acc(&mut grads[b.0], Tensor::from_f32(&[batch * sb, d], db));
                }
                Op::SeqFirst { x, batch, s } => {
                    let d = gout.shape[1];
                    let mut dx = vec![0.0f32; batch * s * d];
                    for bi in 0..*batch {
                        dx[bi * s * d..bi * s * d + d]
                            .copy_from_slice(&gout.f32s()[bi * d..(bi + 1) * d]);
                    }
                    acc(&mut grads[x.0], Tensor::from_f32(&[batch * s, d], dx));
                }
                Op::SeqMean { x, batch, s } => {
                    let d = gout.shape[1];
                    let inv = 1.0 / *s as f32;
                    let mut dx = vec![0.0f32; batch * s * d];
                    for bi in 0..*batch {
                        let grow = &gout.f32s()[bi * d..(bi + 1) * d];
                        for r in 0..*s {
                            let dst = &mut dx[(bi * s + r) * d..(bi * s + r + 1) * d];
                            for (a, &v) in dst.iter_mut().zip(grow) {
                                *a = v * inv;
                            }
                        }
                    }
                    acc(&mut grads[x.0], Tensor::from_f32(&[batch * s, d], dx));
                }
                Op::MaskedXent { logits, labels, count } => {
                    let dl =
                        ops::masked_xent_bwd(self.value(*logits), labels, *count, gout.item());
                    acc(&mut grads[logits.0], dl);
                }
            }
        }
        grads
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::store::Store;
    use crate::util::rng::Rng;

    fn rand_t(shape: &[usize], rng: &mut Rng) -> Tensor {
        let n = crate::tensor::numel(shape);
        Tensor::from_f32(shape, (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect())
    }

    /// Evaluate the composite graph used by the FD test below on explicit
    /// leaf tensors; returns the scalar loss.
    fn graph_loss(leaves: &Store) -> f32 {
        let mut tape = Tape::new();
        let emb = tape.leaf(leaves.expect("emb").clone());
        let t = tape.leaf(leaves.expect("t").clone());
        let v = tape.leaf(leaves.expect("v").clone());
        let b = tape.leaf(leaves.expect("b").clone());
        let w = tape.leaf(leaves.expect("w").clone());
        let g1 = tape.gather(emb, vec![0, 2, 4, 1]);
        let g2 = tape.add_tiled(g1, t, 2);
        let g3 = tape.mul_row(g2, v);
        let g4 = tape.add_row(g3, b);
        let lin = tape.linear(g4, w);
        let loss = tape.masked_xent(lin, vec![1, -1, 0, 3]);
        tape.value(loss).item()
    }

    #[test]
    fn composite_graph_fd_gradients() {
        let mut rng = Rng::new(17);
        let mut leaves = Store::new();
        leaves.insert("emb", rand_t(&[5, 3], &mut rng));
        leaves.insert("t", rand_t(&[2, 3], &mut rng));
        leaves.insert("v", rand_t(&[3], &mut rng));
        leaves.insert("b", rand_t(&[3], &mut rng));
        leaves.insert("w", rand_t(&[4, 3], &mut rng));

        // analytic gradients
        let mut tape = Tape::new();
        let names = ["emb", "t", "v", "b", "w"];
        let vars: Vec<Var> = names.iter().map(|n| tape.leaf(leaves.expect(n).clone())).collect();
        let g1 = tape.gather(vars[0], vec![0, 2, 4, 1]);
        let g2 = tape.add_tiled(g1, vars[1], 2);
        let g3 = tape.mul_row(g2, vars[2]);
        let g4 = tape.add_row(g3, vars[3]);
        let lin = tape.linear(g4, vars[4]);
        let loss = tape.masked_xent(lin, vec![1, -1, 0, 3]);
        let grads = tape.backward(loss);

        let eps = 1e-2f32;
        for (name, var) in names.iter().zip(&vars) {
            let g = grads[var.index()].as_ref().expect("leaf gradient");
            for i in 0..g.numel() {
                let mut plus = leaves.clone();
                plus.get_mut(name).unwrap().f32s_mut()[i] += eps;
                let mut minus = leaves.clone();
                minus.get_mut(name).unwrap().f32s_mut()[i] -= eps;
                let fd = (graph_loss(&plus) - graph_loss(&minus)) / (2.0 * eps);
                let a = g.f32s()[i];
                let rel = (a - fd).abs() / a.abs().max(fd.abs()).max(1.0);
                assert!(rel < 1e-3, "{name}[{i}]: analytic {a} vs fd {fd}");
            }
        }
    }

    #[test]
    fn shared_leaf_accumulates_both_uses() {
        // loss = xent(x @ x^T): the leaf feeds the op twice; its gradient
        // must be the sum of both path contributions (FD-checked).
        let mut rng = Rng::new(5);
        let x0 = rand_t(&[3, 3], &mut rng);
        let f = |x: &Tensor| {
            let mut tape = Tape::new();
            let x = tape.leaf(x.clone());
            let y = tape.linear(x, x);
            let loss = tape.masked_xent(y, vec![0, 2, 1]);
            (tape, x, loss)
        };
        let (tape, xv, loss) = f(&x0);
        let grads = tape.backward(loss);
        let g = grads[xv.index()].as_ref().unwrap();
        let eps = 1e-2f32;
        for i in 0..x0.numel() {
            let mut p = x0.clone();
            p.f32s_mut()[i] += eps;
            let mut m = x0.clone();
            m.f32s_mut()[i] -= eps;
            let lp = {
                let (t, _, l) = f(&p);
                t.value(l).item()
            };
            let lm = {
                let (t, _, l) = f(&m);
                t.value(l).item()
            };
            let fd = (lp - lm) / (2.0 * eps);
            let a = g.f32s()[i];
            let rel = (a - fd).abs() / a.abs().max(fd.abs()).max(1.0);
            assert!(rel < 1e-3, "x[{i}]: analytic {a} vs fd {fd}");
        }
    }

    #[test]
    fn seq_ops_roundtrip_values_and_gradients() {
        let mut tape = Tape::new();
        let cls = tape.leaf(Tensor::from_f32(&[2], vec![1.0, 2.0]));
        let patches = tape.leaf(Tensor::from_f32(&[4, 2], vec![0.1; 8]));
        let bc = tape.broadcast_row(cls, 2); // (2 batches, 1 row each)
        let cat = tape.concat_seq(bc, patches, 2, 1, 2); // (2*(1+2), 2)
        assert_eq!(tape.value(cat).shape, vec![6, 2]);
        assert_eq!(tape.value(cat).at2(0, 1), 2.0); // cls row leads each block
        assert_eq!(tape.value(cat).at2(3, 0), 1.0);
        let first = tape.seq_first(cat, 2, 3);
        assert_eq!(tape.value(first).f32s(), &[1.0, 2.0, 1.0, 2.0]);
        let mean = tape.seq_mean(cat, 2, 3);
        assert!((tape.value(mean).at2(0, 0) - (1.0 + 0.1 + 0.1) / 3.0).abs() < 1e-6);
        // dummy scalar through a linear head for the backward sweep
        let w = tape.leaf(Tensor::from_f32(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]));
        let lin = tape.linear(mean, w);
        let loss = tape.masked_xent(lin, vec![0, 1]);
        let grads = tape.backward(loss);
        assert!(grads[cls.index()].is_some(), "cls leaf must receive gradient");
        assert!(grads[patches.index()].is_some());
    }
}
