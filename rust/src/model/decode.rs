//! Tape-free incremental decode for the GPT family: per-layer paged KV
//! caches plus a batched single-token forward step.
//!
//! The training engine only has full-sequence forwards; serving a (grown)
//! GPT needs the complementary path — prefill a prompt once, then feed one
//! token per step while attending over cached K/V. Three invariants pin
//! this module to the already-trusted training forward (asserted in
//! `tests/decode_parity.rs`):
//!
//! * [`Decoder::forward_full`] uses the *training* kernels
//!   ([`ops::linear_fused`], [`ops::attention_fwd`]) at batch 1, so its
//!   final hidden states are bitwise equal to the training tape's.
//! * [`Decoder::decode_step`] uses the batch-invariant decode kernels
//!   ([`ops::linear_dot`], [`ops::attention_decode`]) — a session decoded
//!   alone is bitwise equal to the same session decoded inside any batch,
//!   which is what makes the continuous-batching scheduler deterministic.
//! * On shapes under the packing threshold both kernel families take the
//!   same dot-product path, so incremental decode is *bitwise* equal to
//!   the full-sequence forward there (and ≤1e-5 relative everywhere).
//!
//! All intermediates come from [`arena`] and K/V pages from a
//! [`PagePool`], so a warm decode loop performs zero fresh allocations.

use crate::bail;
use crate::config::ModelConfig;
use crate::error::{Context, Result};
use crate::tensor::arena;
use crate::tensor::ops::{self, Act, AttnShape};
use crate::tensor::paged::{PagePool, PagedRows};
use crate::tensor::Tensor;

use super::{param_shapes, ParamView};

/// Per-session, per-layer K/V page tables over a shared [`PagePool`].
/// One page holds `page_tokens` rows of `dim` floats; K and V of each
/// layer grow their own tables. `len` counts committed tokens — a decode
/// step writes at position `len` in every layer, then [`KvCache::commit`]s
/// once.
#[derive(Debug)]
pub struct KvCache {
    k_tables: Vec<Vec<usize>>,
    v_tables: Vec<Vec<usize>>,
    len: usize,
    capacity: usize,
    page_tokens: usize,
    dim: usize,
}

impl KvCache {
    pub fn new(layers: usize, page_tokens: usize, dim: usize, capacity: usize) -> KvCache {
        assert!(page_tokens > 0 && dim > 0 && layers > 0);
        KvCache {
            // lint-free by construction: page tables are usize metadata,
            // not f32 buffers — only the pool touches the arena
            k_tables: (0..layers).map(|_| Vec::new()).collect(),
            v_tables: (0..layers).map(|_| Vec::new()).collect(),
            len: 0,
            capacity,
            page_tokens,
            dim,
        }
    }

    /// Committed token count.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Pages per layer-side table a `len`-token cache needs.
    fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.page_tokens)
    }

    /// Write one K and one V row at `pos` of `layer`, growing the page
    /// tables from the pool as `pos` crosses page boundaries. `pos` must
    /// lie in `[len, capacity)` — prefill writes a run of positions before
    /// one commit; a decode step writes exactly `len`. On a capped pool
    /// ([`PagePool::with_capacity`]) exhaustion surfaces as a typed error
    /// — the serve scheduler's admission sizing makes it unreachable there,
    /// but the cache itself must degrade gracefully, never panic.
    pub fn write_kv(
        &mut self,
        pool: &mut PagePool,
        layer: usize,
        pos: usize,
        krow: &[f32],
        vrow: &[f32],
    ) -> Result<()> {
        assert!(pos >= self.len && pos < self.capacity, "write_kv pos {pos} outside [{}, {})", self.len, self.capacity);
        assert_eq!(krow.len(), self.dim);
        assert_eq!(vrow.len(), self.dim);
        assert_eq!(pool.page_floats(), self.page_tokens * self.dim, "pool page size mismatch");
        let need = self.pages_for(pos + 1);
        while self.k_tables[layer].len() < need || self.v_tables[layer].len() < need {
            let table = if self.k_tables[layer].len() < need {
                &mut self.k_tables[layer]
            } else {
                &mut self.v_tables[layer]
            };
            match pool.try_alloc() {
                Some(page) => table.push(page),
                None => bail!(
                    "page pool exhausted: {} pages live at the {} page cap \
                     (KV write at layer {layer}, pos {pos})",
                    pool.live(),
                    pool.capacity()
                ),
            }
        }
        let off = (pos % self.page_tokens) * self.dim;
        let kp = pool.page_mut(self.k_tables[layer][pos / self.page_tokens]);
        kp[off..off + self.dim].copy_from_slice(krow);
        let vp = pool.page_mut(self.v_tables[layer][pos / self.page_tokens]);
        vp[off..off + self.dim].copy_from_slice(vrow);
        Ok(())
    }

    /// Commit `n` freshly written positions (all layers must have been
    /// written for each of them).
    pub fn commit(&mut self, n: usize) {
        assert!(self.len + n <= self.capacity);
        self.len += n;
    }

    /// View of the first `upto` K rows of `layer` (may exceed `len` by the
    /// not-yet-committed positions a step just wrote).
    pub fn k_view<'a>(&'a self, pool: &'a PagePool, layer: usize, upto: usize) -> PagedRows<'a> {
        PagedRows::new(pool, &self.k_tables[layer], self.page_tokens, self.dim, upto)
    }

    pub fn v_view<'a>(&'a self, pool: &'a PagePool, layer: usize, upto: usize) -> PagedRows<'a> {
        PagedRows::new(pool, &self.v_tables[layer], self.page_tokens, self.dim, upto)
    }

    /// Return every page to the pool's free list (session eviction).
    pub fn release(&mut self, pool: &mut PagePool) {
        for table in self.k_tables.iter_mut().chain(self.v_tables.iter_mut()) {
            for page in table.drain(..) {
                pool.free(page);
            }
        }
        self.len = 0;
    }
}

/// Borrowed per-layer parameters of one pre-LN GPT block.
struct LayerParams<'a> {
    ln1_g: &'a Tensor,
    ln1_b: &'a Tensor,
    q_w: &'a Tensor,
    q_b: &'a Tensor,
    k_w: &'a Tensor,
    k_b: &'a Tensor,
    v_w: &'a Tensor,
    v_b: &'a Tensor,
    o_w: &'a Tensor,
    o_b: &'a Tensor,
    ln2_g: &'a Tensor,
    ln2_b: &'a Tensor,
    fc1_w: &'a Tensor,
    fc1_b: &'a Tensor,
    fc2_w: &'a Tensor,
    fc2_b: &'a Tensor,
}

/// One token of one session entering a batched decode step.
#[derive(Debug, Clone, Copy)]
pub struct StepInput {
    pub token: i32,
    pub pos: usize,
}

/// Zero-copy decode view over a GPT parameter set: every tensor is
/// borrowed (the same discipline as the training tape's leaves), validated
/// against [`param_shapes`] once at construction.
pub struct Decoder<'a> {
    cfg: &'a ModelConfig,
    emb_tok: &'a Tensor,
    emb_pos: &'a Tensor,
    mlm_bias: &'a Tensor,
    final_ln_g: &'a Tensor,
    final_ln_b: &'a Tensor,
    layers: Vec<LayerParams<'a>>,
}

impl<'a> Decoder<'a> {
    pub fn new<P: ParamView>(cfg: &'a ModelConfig, params: &'a P) -> Result<Decoder<'a>> {
        if cfg.family != "gpt" {
            bail!("decode serves the gpt family, not '{}' ('{}')", cfg.family, cfg.name);
        }
        if cfg.n_classes > 0 {
            bail!("decode needs the tied LM head; '{}' is a probe config", cfg.name);
        }
        let get = |name: &str| -> Result<&'a Tensor> {
            params
                .tensor(name)
                .with_context(|| format!("params for '{}' missing '{name}'", cfg.name))
        };
        for (name, shape) in param_shapes(cfg) {
            let t = get(&name)?;
            if t.shape != shape {
                bail!("param '{name}' shape {:?} != expected {:?} for '{}'", t.shape, shape, cfg.name);
            }
        }
        let mut layers = Vec::with_capacity(cfg.layers);
        for l in 0..cfg.layers {
            let p = format!("L{l:02}_");
            layers.push(LayerParams {
                ln1_g: get(&format!("{p}ln1_g"))?,
                ln1_b: get(&format!("{p}ln1_b"))?,
                q_w: get(&format!("{p}q_w"))?,
                q_b: get(&format!("{p}q_b"))?,
                k_w: get(&format!("{p}k_w"))?,
                k_b: get(&format!("{p}k_b"))?,
                v_w: get(&format!("{p}v_w"))?,
                v_b: get(&format!("{p}v_b"))?,
                o_w: get(&format!("{p}o_w"))?,
                o_b: get(&format!("{p}o_b"))?,
                ln2_g: get(&format!("{p}ln2_g"))?,
                ln2_b: get(&format!("{p}ln2_b"))?,
                fc1_w: get(&format!("{p}fc1_w"))?,
                fc1_b: get(&format!("{p}fc1_b"))?,
                fc2_w: get(&format!("{p}fc2_w"))?,
                fc2_b: get(&format!("{p}fc2_b"))?,
            });
        }
        Ok(Decoder {
            cfg,
            emb_tok: get("emb_tok")?,
            emb_pos: get("emb_pos")?,
            mlm_bias: get("mlm_bias")?,
            final_ln_g: get("final_ln_g")?,
            final_ln_b: get("final_ln_b")?,
            layers,
        })
    }

    pub fn cfg(&self) -> &ModelConfig {
        self.cfg
    }

    /// The tied LM head `(emb_tok, mlm_bias)` — what
    /// [`ops::lm_head_sample`] / [`ops::lm_head_argmax`] project hidden
    /// states through.
    pub fn head(&self) -> (&Tensor, &Tensor) {
        (self.emb_tok, self.mlm_bias)
    }

    fn check_tokens(&self, tokens: &[i32]) -> Result<()> {
        if tokens.is_empty() || tokens.len() > self.cfg.seq {
            bail!("prompt length {} outside [1, {}] for '{}'", tokens.len(), self.cfg.seq, self.cfg.name);
        }
        if let Some(&bad) = tokens.iter().find(|&&t| t < 0 || t as usize >= self.cfg.vocab) {
            bail!("token id {bad} outside vocab {} for '{}'", self.cfg.vocab, self.cfg.name);
        }
        Ok(())
    }

    /// Full-sequence forward over a token prefix with the **training**
    /// kernels at batch 1: gather + tiled position add, pre-LN blocks with
    /// causal [`ops::attention_fwd`], final layernorm. Returns the
    /// (t, dim) final hidden states — bitwise equal to the training tape's
    /// `xf` over the same prefix (the decode-parity anchor).
    pub fn forward_full(&self, tokens: &[i32]) -> Result<Tensor> {
        self.forward_inner(tokens, None)
    }

    /// [`Decoder::forward_full`] that additionally writes every layer's
    /// K/V rows into `cache` (positions `0..tokens.len()`) and commits
    /// them — the prompt-ingestion phase of a session.
    pub fn prefill(
        &self,
        tokens: &[i32],
        cache: &mut KvCache,
        pool: &mut PagePool,
    ) -> Result<Tensor> {
        if cache.len() != 0 {
            bail!("prefill into a non-empty cache (len {})", cache.len());
        }
        self.forward_inner(tokens, Some((cache, pool)))
    }

    fn forward_inner(
        &self,
        tokens: &[i32],
        mut sink: Option<(&mut KvCache, &mut PagePool)>,
    ) -> Result<Tensor> {
        self.check_tokens(tokens)?;
        let (t, d) = (tokens.len(), self.cfg.dim);
        let (ev, pv) = (self.emb_tok.f32s(), self.emb_pos.f32s());
        let mut xbuf = arena::alloc_scratch(t * d);
        for (i, &tok) in tokens.iter().enumerate() {
            let erow = &ev[tok as usize * d..(tok as usize + 1) * d];
            let prow = &pv[i * d..(i + 1) * d];
            for ((x, &e), &p) in xbuf[i * d..(i + 1) * d].iter_mut().zip(erow).zip(prow) {
                *x = e + p;
            }
        }
        let mut x = Tensor::from_f32(&[t, d], xbuf);
        let sh = AttnShape { batch: 1, heads: self.cfg.heads, s_q: t, s_k: t, causal: true };
        for (l, lp) in self.layers.iter().enumerate() {
            let (h, stats) = ops::layernorm_fwd(&x, lp.ln1_g, lp.ln1_b);
            arena::recycle_buf(stats);
            let (q, _) = ops::linear_fused(&h, lp.q_w, Some(lp.q_b), Act::None);
            let (k, _) = ops::linear_fused(&h, lp.k_w, Some(lp.k_b), Act::None);
            let (v, _) = ops::linear_fused(&h, lp.v_w, Some(lp.v_b), Act::None);
            arena::recycle(h);
            if let Some((cache, pool)) = sink.as_mut() {
                let (kv, vv) = (k.f32s(), v.f32s());
                for pos in 0..t {
                    cache.write_kv(
                        pool,
                        l,
                        pos,
                        &kv[pos * d..(pos + 1) * d],
                        &vv[pos * d..(pos + 1) * d],
                    )?;
                }
            }
            let (att, probs) = ops::attention_fwd(&q, &k, &v, &sh);
            arena::recycle(probs);
            arena::recycle(q);
            arena::recycle(k);
            arena::recycle(v);
            let (o, _) = ops::linear_fused(&att, lp.o_w, Some(lp.o_b), Act::None);
            arena::recycle(att);
            for (xi, &oi) in x.f32s_mut().iter_mut().zip(o.f32s()) {
                *xi += oi;
            }
            arena::recycle(o);
            let (h2, stats) = ops::layernorm_fwd(&x, lp.ln2_g, lp.ln2_b);
            arena::recycle_buf(stats);
            let (a, pre) = ops::linear_fused(&h2, lp.fc1_w, Some(lp.fc1_b), Act::Gelu);
            if let Some(pre) = pre {
                arena::recycle(pre);
            }
            arena::recycle(h2);
            let (f2, _) = ops::linear_fused(&a, lp.fc2_w, Some(lp.fc2_b), Act::None);
            arena::recycle(a);
            for (xi, &fi) in x.f32s_mut().iter_mut().zip(f2.f32s()) {
                *xi += fi;
            }
            arena::recycle(f2);
        }
        let (xf, stats) = ops::layernorm_fwd(&x, self.final_ln_g, self.final_ln_b);
        arena::recycle_buf(stats);
        arena::recycle(x);
        if let Some((cache, _)) = sink.as_mut() {
            cache.commit(t);
        }
        Ok(xf)
    }

    /// One batched incremental decode step: each feed contributes one token
    /// at its session's next position, attending over that session's cached
    /// K/V (plus the row this step writes). Returns the (sessions, dim)
    /// final-layernorm hidden states; every cache is committed by one
    /// position. Per-session results are bitwise independent of the batch
    /// composition (see the module docs), so any admit/evict interleaving
    /// reproduces the solo token streams.
    pub fn decode_step(
        &self,
        feeds: &[StepInput],
        caches: &mut [KvCache],
        pool: &mut PagePool,
    ) -> Result<Tensor> {
        let (s_n, d) = (feeds.len(), self.cfg.dim);
        if s_n == 0 {
            bail!("decode_step with no sessions");
        }
        if caches.len() != s_n {
            bail!("decode_step: {} feeds vs {} caches", s_n, caches.len());
        }
        for (f, c) in feeds.iter().zip(caches.iter()) {
            if f.token < 0 || f.token as usize >= self.cfg.vocab {
                bail!("token id {} outside vocab {}", f.token, self.cfg.vocab);
            }
            if f.pos != c.len() {
                bail!("feed pos {} != cache len {}", f.pos, c.len());
            }
            if f.pos >= self.cfg.seq {
                bail!("position {} outside seq {} for '{}'", f.pos, self.cfg.seq, self.cfg.name);
            }
        }
        let (ev, pv) = (self.emb_tok.f32s(), self.emb_pos.f32s());
        let mut xbuf = arena::alloc_scratch(s_n * d);
        for (s, f) in feeds.iter().enumerate() {
            let erow = &ev[f.token as usize * d..(f.token as usize + 1) * d];
            let prow = &pv[f.pos * d..(f.pos + 1) * d];
            for ((x, &e), &p) in xbuf[s * d..(s + 1) * d].iter_mut().zip(erow).zip(prow) {
                *x = e + p;
            }
        }
        let mut x = Tensor::from_f32(&[s_n, d], xbuf);
        let mut att = Tensor::from_f32(&[s_n, d], arena::alloc_scratch(s_n * d));
        let mut scores = arena::alloc_scratch(self.cfg.seq);
        for (l, lp) in self.layers.iter().enumerate() {
            let (h, stats) = ops::layernorm_fwd(&x, lp.ln1_g, lp.ln1_b);
            arena::recycle_buf(stats);
            let q = ops::linear_dot(&h, lp.q_w, Some(lp.q_b), Act::None);
            let k = ops::linear_dot(&h, lp.k_w, Some(lp.k_b), Act::None);
            let v = ops::linear_dot(&h, lp.v_w, Some(lp.v_b), Act::None);
            arena::recycle(h);
            let (kv, vv) = (k.f32s(), v.f32s());
            for (s, (f, cache)) in feeds.iter().zip(caches.iter_mut()).enumerate() {
                cache.write_kv(pool, l, f.pos, &kv[s * d..(s + 1) * d], &vv[s * d..(s + 1) * d])?;
            }
            {
                let qv = q.f32s();
                let av = att.f32s_mut();
                for (s, (f, cache)) in feeds.iter().zip(caches.iter()).enumerate() {
                    let kview = cache.k_view(pool, l, f.pos + 1);
                    let vview = cache.v_view(pool, l, f.pos + 1);
                    ops::attention_decode(
                        &qv[s * d..(s + 1) * d],
                        &kview,
                        &vview,
                        self.cfg.heads,
                        &mut scores,
                        &mut av[s * d..(s + 1) * d],
                    );
                }
            }
            arena::recycle(q);
            arena::recycle(k);
            arena::recycle(v);
            let o = ops::linear_dot(&att, lp.o_w, Some(lp.o_b), Act::None);
            for (xi, &oi) in x.f32s_mut().iter_mut().zip(o.f32s()) {
                *xi += oi;
            }
            arena::recycle(o);
            let (h2, stats) = ops::layernorm_fwd(&x, lp.ln2_g, lp.ln2_b);
            arena::recycle_buf(stats);
            let a = ops::linear_dot(&h2, lp.fc1_w, Some(lp.fc1_b), Act::Gelu);
            arena::recycle(h2);
            let f2 = ops::linear_dot(&a, lp.fc2_w, Some(lp.fc2_b), Act::None);
            arena::recycle(a);
            for (xi, &fi) in x.f32s_mut().iter_mut().zip(f2.f32s()) {
                *xi += fi;
            }
            arena::recycle(f2);
        }
        scores.clear();
        arena::recycle_buf(scores);
        arena::recycle(att);
        let (xf, stats) = ops::layernorm_fwd(&x, self.final_ln_g, self.final_ln_b);
        arena::recycle_buf(stats);
        arena::recycle(x);
        for cache in caches.iter_mut() {
            cache.commit(1);
        }
        Ok(xf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::store::Store;

    fn gpt_cfg() -> ModelConfig {
        ModelConfig {
            name: "tiny_gpt".into(),
            family: "gpt".into(),
            layers: 2,
            dim: 8,
            heads: 2,
            vocab: 24,
            seq: 6,
            batch: 2,
            img: 0,
            patch: 0,
            channels: 3,
            n_classes: 0,
            cls_layers: 0,
            ffn_mult: 4,
        }
    }

    #[test]
    fn decoder_rejects_non_gpt_and_bad_tokens() {
        let mut cfg = gpt_cfg();
        let params = Store::det_init(&param_shapes(&cfg), 1);
        cfg.family = "bert".into();
        assert!(Decoder::new(&cfg, &params).is_err());
        cfg.family = "gpt".into();
        let dec = Decoder::new(&cfg, &params).unwrap();
        assert!(dec.forward_full(&[]).is_err());
        assert!(dec.forward_full(&[0; 7]).is_err());
        assert!(dec.forward_full(&[cfg.vocab as i32]).is_err());
        assert!(dec.forward_full(&[0, 1, 2]).is_ok());
    }

    #[test]
    fn prefill_then_steps_matches_full_forward_bitwise() {
        // tiny shapes sit on the shared dot-product kernel path, so the
        // incremental decode is *bitwise* equal to the full forward
        let cfg = gpt_cfg();
        let params = Store::det_init(&param_shapes(&cfg), 2);
        let dec = Decoder::new(&cfg, &params).unwrap();
        let tokens: Vec<i32> = vec![3, 1, 4, 1, 5];
        let full = dec.forward_full(&tokens).unwrap();
        let mut pool = PagePool::new(2 * cfg.dim);
        let mut cache = KvCache::new(cfg.layers, 2, cfg.dim, cfg.seq);
        let prefix = &tokens[..2];
        let pre = dec.prefill(prefix, &mut cache, &mut pool).unwrap();
        for (g, e) in pre.f32s().iter().zip(&full.f32s()[..2 * cfg.dim]) {
            assert_eq!(g.to_bits(), e.to_bits(), "prefill rows == full forward rows");
        }
        arena::recycle(pre);
        for (pos, &tok) in tokens.iter().enumerate().skip(2) {
            let feeds = [StepInput { token: tok, pos }];
            let xf = dec
                .decode_step(&feeds, std::slice::from_mut(&mut cache), &mut pool)
                .unwrap();
            let want = &full.f32s()[pos * cfg.dim..(pos + 1) * cfg.dim];
            for (g, e) in xf.f32s().iter().zip(want) {
                assert_eq!(g.to_bits(), e.to_bits(), "step {pos} row == full forward row");
            }
            arena::recycle(xf);
        }
        cache.release(&mut pool);
        assert_eq!(pool.live(), 0);
        pool.clear();
    }

    #[test]
    fn cache_release_returns_every_page() {
        let cfg = gpt_cfg();
        let params = Store::det_init(&param_shapes(&cfg), 3);
        let dec = Decoder::new(&cfg, &params).unwrap();
        let mut pool = PagePool::new(2 * cfg.dim);
        let mut a = KvCache::new(cfg.layers, 2, cfg.dim, cfg.seq);
        let mut b = KvCache::new(cfg.layers, 2, cfg.dim, cfg.seq);
        arena::recycle(dec.prefill(&[1, 2, 3], &mut a, &mut pool).unwrap());
        arena::recycle(dec.prefill(&[4, 5], &mut b, &mut pool).unwrap());
        let before = pool.live();
        assert!(before > 0);
        a.release(&mut pool);
        b.release(&mut pool);
        assert_eq!(pool.live(), 0);
        pool.check_invariants().unwrap();
        // a new session reuses the freed pages — no fresh pages
        let (fresh0, _) = pool.stats();
        let mut c = KvCache::new(cfg.layers, 2, cfg.dim, cfg.seq);
        arena::recycle(dec.prefill(&[1, 2, 3], &mut c, &mut pool).unwrap());
        assert_eq!(pool.stats().0, fresh0, "steady-state admit allocates no fresh pages");
        c.release(&mut pool);
        pool.clear();
    }
}
