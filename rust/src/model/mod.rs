//! The native transformer engine: forward passes and full backprop for the
//! paper's text (BERT/GPT) and vision (ViT/CaiT incl. class-attention)
//! families, entirely on the named tensor [`Store`] — no XLA, no AOT
//! artifacts.
//!
//! Layering:
//! * [`tape`] — a minimal reverse-mode autodiff arena over [`Tensor`]s,
//!   built from the NN kernels in [`crate::tensor::ops`] (fused
//!   linear+bias(+GELU), layernorm, softmax attention, masked
//!   cross-entropy, and the streaming fused LM head that computes
//!   linear+cross-entropy one vocab tile at a time — all with analytic
//!   backward kernels, row-parallel via `util::par`).
//! * `text` / `vision` (private) — the family graphs, mirroring
//!   `python/compile/transformer.py` op for op so the native engine and the
//!   AOT artifacts describe the same model.
//! * This root — [`param_shapes`] (the manifest parameter set of a config),
//!   [`loss_only`] / [`loss_and_grads`] (the eval / training entry points
//!   the [`crate::runtime`] `NativeBackend` synthesizes executables from),
//!   [`ParamView`] (the zero-copy parameter lookup both of those are
//!   generic over), and [`supports`].
//!
//! # Memory discipline
//!
//! Parameters enter the graph as **borrowed** tape leaves
//! ([`tape::Tape::param`]) through a [`ParamView`], so a forward/backward
//! pass copies no parameter data — the `NativeBackend` binds its positional
//! inputs as `&Tensor`s straight into the tape. Activations and gradient
//! buffers come from the thread-local [`crate::tensor::arena`] pool and
//! are recycled when the tape drops, so repeated `train_step` calls reach
//! a zero-fresh-allocation steady state (asserted in this module's tests).
//!
//! The engine is also what makes *true task-loss M-learning* possible on
//! the default build: `coordinator::growth_manager` chains
//! [`loss_and_grads`] on the expanded model through the LiGO expansion's
//! analytic backward (`growth::ligo::ligo_apply_backward`) to get dL/dM.

pub mod decode;
pub mod shape;
pub mod tape;
mod text;
mod vision;

use std::collections::BTreeMap;

use crate::bail;
use crate::config::ModelConfig;
use crate::error::{Context, Result};
use crate::tensor::arena;
use crate::tensor::ops;
use crate::tensor::store::Store;
use crate::tensor::Tensor;

use self::tape::{Tape, Var};

/// Read-only parameter lookup the graph builder borrows its tape leaves
/// from. Implemented by [`Store`] (named training state) and by a plain
/// map of borrowed tensors (the `NativeBackend`'s zero-copy view over its
/// positional inputs).
pub trait ParamView {
    /// The tensor registered under `name`, if any.
    fn tensor(&self, name: &str) -> Option<&Tensor>;
}

impl ParamView for Store {
    fn tensor(&self, name: &str) -> Option<&Tensor> {
        self.get(name)
    }
}

impl<'a> ParamView for BTreeMap<&'a str, &'a Tensor> {
    fn tensor(&self, name: &str) -> Option<&Tensor> {
        self.get(name).map(|t| &**t)
    }
}

/// True for the families the native engine implements.
pub fn supports(cfg: &ModelConfig) -> bool {
    matches!(cfg.family.as_str(), "bert" | "gpt" | "vit" | "cait")
}

fn layer_shapes(prefix: &str, d: usize, f: usize, out: &mut Vec<(String, Vec<usize>)>) {
    for m in ["q", "k", "v", "o"] {
        out.push((format!("{prefix}{m}_w"), vec![d, d]));
        out.push((format!("{prefix}{m}_b"), vec![d]));
    }
    out.push((format!("{prefix}fc1_w"), vec![f, d]));
    out.push((format!("{prefix}fc1_b"), vec![f]));
    out.push((format!("{prefix}fc2_w"), vec![d, f]));
    out.push((format!("{prefix}fc2_b"), vec![d]));
    for ln in ["ln1", "ln2"] {
        out.push((format!("{prefix}{ln}_g"), vec![d]));
        out.push((format!("{prefix}{ln}_b"), vec![d]));
    }
}

/// {name -> shape} of every parameter of a config, sorted by name — the
/// exact tensor set of `python/compile/transformer.init_params` and
/// therefore of the AOT manifests' "params" group.
pub fn param_shapes(cfg: &ModelConfig) -> Vec<(String, Vec<usize>)> {
    let (d, f) = (cfg.dim, cfg.ffn());
    let mut v: Vec<(String, Vec<usize>)> = Vec::new();
    if cfg.is_vision() {
        let pdim = cfg.patch * cfg.patch * cfg.channels;
        v.push(("emb_patch_w".into(), vec![d, pdim]));
        v.push(("emb_patch_b".into(), vec![d]));
        v.push(("emb_cls".into(), vec![d]));
        v.push(("emb_pos".into(), vec![cfg.tokens(), d]));
        v.push(("final_ln_g".into(), vec![d]));
        v.push(("final_ln_b".into(), vec![d]));
        v.push(("head_w".into(), vec![cfg.n_classes, d]));
        v.push(("head_b".into(), vec![cfg.n_classes]));
    } else {
        v.push(("emb_tok".into(), vec![cfg.vocab, d]));
        v.push(("emb_pos".into(), vec![cfg.seq, d]));
        v.push(("mlm_bias".into(), vec![cfg.vocab]));
        v.push(("final_ln_g".into(), vec![d]));
        v.push(("final_ln_b".into(), vec![d]));
        if cfg.n_classes > 0 {
            v.push(("head_w".into(), vec![cfg.n_classes, d]));
            v.push(("head_b".into(), vec![cfg.n_classes]));
        }
    }
    for l in 0..cfg.layers {
        let prefix = format!("L{l:02}_");
        layer_shapes(&prefix, d, f, &mut v);
        if cfg.family == "cait" {
            v.push((format!("{prefix}ls1"), vec![d]));
            v.push((format!("{prefix}ls2"), vec![d]));
        }
    }
    for l in 0..cfg.cls_layers {
        layer_shapes(&format!("C{l:02}_"), d, f, &mut v);
    }
    v.sort_by(|a, b| a.0.cmp(&b.0));
    v
}

/// Look up a parameter's tape leaf by name.
fn var(vars: &BTreeMap<String, Var>, name: &str) -> Result<Var> {
    vars.get(name)
        .copied()
        .with_context(|| format!("model params missing tensor '{name}'"))
}

/// Mean accuracy of the classifier head's row-wise argmax against labels
/// (labels < 0 ignored), computed by the streaming tiled
/// [`ops::lm_head_argmax`] — the head logits are never materialized, so the
/// metric stays allocation-free even for large-vocab heads (the same tile
/// loop [`ops::lm_head_xent_fwd`] streams the loss through).
fn head_accuracy(x: &Tensor, w: &Tensor, b: Option<&Tensor>, labels: &[i32]) -> f32 {
    let am = ops::lm_head_argmax(x, w, b);
    let (mut n, mut correct) = (0usize, 0usize);
    for (p, &l) in am.iter().zip(labels) {
        if l < 0 {
            continue;
        }
        n += 1;
        if *p as i32 == l {
            correct += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        correct as f32 / n as f32
    }
}

/// Build the loss graph: returns (tape, loss node, name -> leaf map,
/// metric). Every parameter is validated against [`param_shapes`] and
/// enters the tape as a **borrowed** leaf — the graph holds references
/// into `params` for the tape's lifetime instead of deep copies.
fn build<'p, P: ParamView>(
    cfg: &ModelConfig,
    params: &'p P,
    batch: &Store,
) -> Result<(Tape<'p>, Var, BTreeMap<String, Var>, Option<f32>)> {
    if !supports(cfg) {
        bail!("native model engine does not support family '{}'", cfg.family);
    }
    let mut tape = Tape::new();
    let mut vars: BTreeMap<String, Var> = BTreeMap::new();
    for (name, shape) in param_shapes(cfg) {
        let t = params
            .tensor(&name)
            .with_context(|| format!("params for '{}' missing '{name}'", cfg.name))?;
        if t.shape != shape {
            bail!(
                "param '{name}' shape {:?} != expected {:?} for '{}'",
                t.shape,
                shape,
                cfg.name
            );
        }
        let leaf = tape.param(t);
        vars.insert(name, leaf);
    }
    let (loss, metric) = if cfg.is_vision() {
        vision::vision_loss(&mut tape, &vars, cfg, batch)?
    } else {
        text::text_loss(&mut tape, &vars, cfg, batch)?
    };
    Ok((tape, loss, vars, metric))
}

/// Forward only: (loss, optional metric — accuracy for vision/probe).
pub fn loss_only<P: ParamView>(
    cfg: &ModelConfig,
    params: &P,
    batch: &Store,
) -> Result<(f32, Option<f32>)> {
    let (tape, loss, _vars, metric) = build(cfg, params, batch)?;
    Ok((tape.value(loss).item(), metric))
}

/// Forward + full backward: (loss, gradients, optional metric). The
/// gradient store mirrors the parameter set exactly — parameters a family's
/// loss does not touch get zero gradients. Leaf gradients are *moved* out
/// of the tape (no copy); interior gradients and activations are recycled
/// into the [`arena`] for the next call.
pub fn loss_and_grads<P: ParamView>(
    cfg: &ModelConfig,
    params: &P,
    batch: &Store,
) -> Result<(f32, Store, Option<f32>)> {
    let (tape, loss, vars, metric) = build(cfg, params, batch)?;
    let mut node_grads = tape.backward(loss);
    let mut grads = Store::new();
    for (name, v) in &vars {
        match node_grads[v.index()].take() {
            Some(g) => grads.insert(name.clone(), g),
            None => {
                let shape = &params.tensor(name).expect("validated in build").shape;
                grads.insert(name.clone(), Tensor::zeros(shape));
            }
        }
    }
    // what's left are leaf gradients nothing consumed (e.g. the patchify
    // input's) — return their buffers to the pool
    for g in node_grads.into_iter().flatten() {
        arena::recycle(g);
    }
    Ok((tape.value(loss).item(), grads, metric))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn text_cfg(family: &str, n_classes: usize) -> ModelConfig {
        ModelConfig {
            name: format!("tiny_{family}"),
            family: family.into(),
            layers: 2,
            dim: 8,
            heads: 2,
            vocab: 24,
            seq: 6,
            batch: 2,
            img: 0,
            patch: 0,
            channels: 3,
            n_classes,
            cls_layers: 0,
            ffn_mult: 4,
        }
    }

    fn vision_cfg(family: &str) -> ModelConfig {
        ModelConfig {
            name: format!("tiny_{family}"),
            family: family.into(),
            layers: 2,
            dim: 8,
            heads: 2,
            vocab: 0,
            seq: 0,
            batch: 2,
            img: 8,
            patch: 4,
            channels: 3,
            n_classes: 3,
            cls_layers: usize::from(family == "cait"),
            ffn_mult: 4,
        }
    }

    fn text_batch(cfg: &ModelConfig, seed: u64, probe: bool) -> Store {
        let mut rng = Rng::new(seed);
        let (b, s) = (cfg.batch, cfg.seq);
        let tokens: Vec<i32> = (0..b * s).map(|_| rng.below(cfg.vocab) as i32).collect();
        let mut st = Store::new();
        st.insert("tokens", Tensor::from_i32(&[b, s], tokens.clone()));
        if probe {
            let labels: Vec<i32> = (0..b).map(|_| rng.below(cfg.n_classes) as i32).collect();
            st.insert("labels", Tensor::from_i32(&[b], labels));
        } else {
            // mask ~1/3 of positions (the rest get ignore labels)
            let labels: Vec<i32> = tokens
                .iter()
                .map(|&t| if rng.coin(0.34) { t } else { -1 })
                .collect();
            st.insert("labels", Tensor::from_i32(&[b, s], labels));
        }
        st
    }

    fn vision_batch(cfg: &ModelConfig, seed: u64) -> Store {
        let mut rng = Rng::new(seed);
        let b = cfg.batch;
        let n = b * cfg.img * cfg.img * cfg.channels;
        let images: Vec<f32> = (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let labels: Vec<i32> = (0..b).map(|_| rng.below(cfg.n_classes) as i32).collect();
        let mut st = Store::new();
        st.insert(
            "images",
            Tensor::from_f32(&[b, cfg.img, cfg.img, cfg.channels], images),
        );
        st.insert("labels", Tensor::from_i32(&[b], labels));
        st
    }

    /// Per-entry central-difference check on a random sample of entries of
    /// every parameter tensor: |analytic - fd| <= 1e-3 * max(|.|, 1).
    fn fd_check_params(cfg: &ModelConfig, params: &Store, batch: &Store, seed: u64) {
        let (l0, grads, _m) = loss_and_grads(cfg, params, batch).unwrap();
        assert!(l0.is_finite(), "loss must be finite");
        let eps = 1e-2f32;
        let mut rng = Rng::new(seed);
        for (name, g) in grads.iter() {
            for _ in 0..2 {
                let i = rng.below(g.numel());
                let mut plus = params.clone();
                plus.get_mut(name).unwrap().f32s_mut()[i] += eps;
                let mut minus = params.clone();
                minus.get_mut(name).unwrap().f32s_mut()[i] -= eps;
                let (lp, _) = loss_only(cfg, &plus, batch).unwrap();
                let (lm, _) = loss_only(cfg, &minus, batch).unwrap();
                let fd = (lp - lm) / (2.0 * eps);
                let a = g.f32s()[i];
                let rel = (a - fd).abs() / a.abs().max(fd.abs()).max(1.0);
                assert!(rel < 1e-3, "{name}[{i}]: analytic {a} vs fd {fd} (rel {rel})");
            }
        }
    }

    #[test]
    fn bert_fd_gradients() {
        let cfg = text_cfg("bert", 0);
        let params = Store::det_init(&param_shapes(&cfg), 1);
        fd_check_params(&cfg, &params, &text_batch(&cfg, 3, false), 10);
    }

    #[test]
    fn gpt_fd_gradients() {
        let cfg = text_cfg("gpt", 0);
        let params = Store::det_init(&param_shapes(&cfg), 2);
        fd_check_params(&cfg, &params, &text_batch(&cfg, 4, false), 11);
    }

    #[test]
    fn probe_fd_gradients_and_unused_params_get_zero() {
        let cfg = text_cfg("bert", 3);
        let params = Store::det_init(&param_shapes(&cfg), 3);
        let batch = text_batch(&cfg, 5, true);
        fd_check_params(&cfg, &params, &batch, 12);
        // the probe head never touches mlm_bias: its grad must be all-zero
        let (_l, grads, metric) = loss_and_grads(&cfg, &params, &batch).unwrap();
        assert!(grads.expect("mlm_bias").f32s().iter().all(|&x| x == 0.0));
        let acc = metric.expect("probe reports accuracy");
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn vit_fd_gradients() {
        let cfg = vision_cfg("vit");
        let params = Store::det_init(&param_shapes(&cfg), 4);
        fd_check_params(&cfg, &params, &vision_batch(&cfg, 6), 13);
    }

    #[test]
    fn cait_fd_gradients_cover_class_attention() {
        let cfg = vision_cfg("cait");
        let params = Store::det_init(&param_shapes(&cfg), 5);
        let batch = vision_batch(&cfg, 7);
        fd_check_params(&cfg, &params, &batch, 14);
        // class-attention parameters must receive gradient
        let (_l, grads, _m) = loss_and_grads(&cfg, &params, &batch).unwrap();
        assert!(grads.expect("C00_q_w").f32s().iter().any(|&x| x != 0.0));
        assert!(grads.expect("L00_ls1").f32s().iter().any(|&x| x != 0.0));
    }

    #[test]
    fn init_loss_near_uniform_entropy() {
        // det-init logits are tiny, so the initial loss sits near ln(V)
        // (text) / ln(classes) (vision) — the "non-trivial curve" anchor.
        let cfg = text_cfg("bert", 0);
        let params = Store::det_init(&param_shapes(&cfg), 0);
        let (l, _) = loss_only(&cfg, &params, &text_batch(&cfg, 1, false)).unwrap();
        assert!((l - (cfg.vocab as f32).ln()).abs() < 0.3, "bert init loss {l}");
        let vcfg = vision_cfg("vit");
        let vp = Store::det_init(&param_shapes(&vcfg), 0);
        let (vl, _) = loss_only(&vcfg, &vp, &vision_batch(&vcfg, 1)).unwrap();
        assert!((vl - (vcfg.n_classes as f32).ln()).abs() < 0.3, "vit init loss {vl}");
    }

    #[test]
    fn gpt_causality_matters_and_engine_is_deterministic() {
        // identical params/batch: bert (bidirectional) and gpt (causal)
        // bodies must produce different losses; repeated runs identical.
        let bc = text_cfg("bert", 0);
        let mut gc = text_cfg("gpt", 0);
        gc.name = bc.name.clone();
        let params = Store::det_init(&param_shapes(&bc), 6);
        let batch = text_batch(&bc, 8, false);
        let (lb, _) = loss_only(&bc, &params, &batch).unwrap();
        let (lg, _) = loss_only(&gc, &params, &batch).unwrap();
        assert_ne!(lb, lg, "causal mask must change the loss");
        let (lb2, _) = loss_only(&bc, &params, &batch).unwrap();
        assert_eq!(lb, lb2, "engine must be deterministic");
        let (g1, _g, _) = loss_and_grads(&bc, &params, &batch).unwrap();
        assert_eq!(lb, g1, "grad pass computes the same loss");
    }

    #[test]
    fn rejects_bad_inputs_with_typed_errors() {
        let cfg = text_cfg("bert", 0);
        let params = Store::det_init(&param_shapes(&cfg), 0);
        // missing batch keys
        assert!(loss_only(&cfg, &params, &Store::new()).is_err());
        // token out of vocab
        let mut bad = text_batch(&cfg, 1, false);
        bad.get_mut("tokens").unwrap().i32s_mut()[0] = cfg.vocab as i32;
        assert!(loss_only(&cfg, &params, &bad).is_err());
        // missing a parameter
        let mut p2 = params.clone();
        p2.remove("L00_q_w");
        assert!(loss_only(&cfg, &p2, &text_batch(&cfg, 1, false)).is_err());
        // unsupported family
        let mut ucfg = cfg.clone();
        ucfg.family = "rnn".into();
        assert!(loss_only(&ucfg, &params, &text_batch(&cfg, 1, false)).is_err());
    }

    #[test]
    fn forward_borrows_params_and_reuses_arena_buffers() {
        let cfg = text_cfg("bert", 0);
        let params = Store::det_init(&param_shapes(&cfg), 7);
        let batch = text_batch(&cfg, 9, false);
        // 1) zero-copy leaves: the tape's parameter values alias the
        // Store's tensors (no per-leaf clone anywhere in the forward)
        {
            let (tape, _loss, vars, _m) = build(&cfg, &params, &batch).unwrap();
            for name in ["emb_tok", "L00_q_w", "L01_fc1_w", "final_ln_g"] {
                let v = vars[name];
                assert!(
                    std::ptr::eq(tape.value(v), params.get(name).unwrap()),
                    "{name} must be borrowed, not copied"
                );
            }
        }
        // 2) steady state allocates nothing fresh: warm the pool with one
        // full step, recycle its outputs (exactly what Trainer::train_step
        // does with the consumed gradient store), then count again
        if arena::enabled() {
            arena::clear();
            let (_l, g1, _m) = loss_and_grads(&cfg, &params, &batch).unwrap();
            arena::recycle_store(g1);
            arena::reset_stats();
            let (_l2, g2, _m2) = loss_and_grads(&cfg, &params, &batch).unwrap();
            let (fresh, reused) = arena::stats();
            assert_eq!(fresh, 0, "steady-state step must reuse every pooled buffer");
            assert!(reused > 0, "the pool must actually be exercised");
            arena::recycle_store(g2);
        }
    }

    /// The streaming fused LM head against the unfused linear+masked_xent
    /// lowering, whole-model: same loss, same metric, and every parameter
    /// gradient equal to ≤1e-5 relative — across the tied-head LM families
    /// (bert/gpt), the probe head, and both vision classifiers.
    #[test]
    fn fused_and_unfused_lm_head_agree_end_to_end() {
        let run = |cfg: &ModelConfig, params: &Store, batch: &Store, fused: bool| {
            ops::set_fused_xent_override(Some(fused));
            let out = loss_and_grads(cfg, params, batch).unwrap();
            ops::set_fused_xent_override(None);
            out
        };
        let mut cases: Vec<(ModelConfig, Store, Store)> = Vec::new();
        for (family, probe) in [("bert", false), ("gpt", false), ("bert", true)] {
            let cfg = text_cfg(family, if probe { 3 } else { 0 });
            let params = Store::det_init(&param_shapes(&cfg), 21);
            let batch = text_batch(&cfg, 22, probe);
            cases.push((cfg, params, batch));
        }
        for family in ["vit", "cait"] {
            let cfg = vision_cfg(family);
            let params = Store::det_init(&param_shapes(&cfg), 23);
            let batch = vision_batch(&cfg, 24);
            cases.push((cfg, params, batch));
        }
        for (cfg, params, batch) in &cases {
            let (lf, gf, mf) = run(cfg, params, batch, true);
            let (lu, gu, mu) = run(cfg, params, batch, false);
            assert!(
                (lf - lu).abs() <= 1e-5 * lf.abs().max(1.0),
                "{}: fused loss {lf} vs unfused {lu}",
                cfg.name
            );
            assert_eq!(mf, mu, "{}: metric must not depend on the lowering", cfg.name);
            for (name, g) in gf.iter() {
                let gu_t = gu.expect(name);
                for (a, b) in g.f32s().iter().zip(gu_t.f32s()) {
                    let rel = (a - b).abs() / a.abs().max(b.abs()).max(1.0);
                    assert!(rel <= 1e-5, "{}::{name}: fused {a} vs unfused {b}", cfg.name);
                }
            }
        }
    }

    /// The acceptance property of the streaming LM head: with the fused
    /// path on, **no buffer of `rows * vocab` elements is ever requested**
    /// in forward or backward — the arena's high-water mark stays strictly
    /// below the logits size the unfused chain needs (and the unfused run
    /// proves the probe would catch one).
    #[test]
    fn streaming_lm_head_never_requests_a_logits_buffer() {
        if !arena::enabled() {
            return; // LIGO_ARENA=0 run: the high-water probe is off
        }
        let mut cfg = text_cfg("bert", 0);
        // a shape where rows * vocab strictly dominates every legitimate
        // buffer (activations, attention probs, packed transposes, grads)
        cfg.vocab = 512;
        cfg.seq = 32;
        cfg.batch = 2;
        let params = Store::det_init(&param_shapes(&cfg), 8);
        let batch = text_batch(&cfg, 11, false);
        let rows_by_vocab = cfg.batch * cfg.seq * cfg.vocab;
        ops::set_fused_xent_override(Some(true));
        arena::clear();
        arena::reset_stats();
        let (_l, g, _m) = loss_and_grads(&cfg, &params, &batch).unwrap();
        arena::recycle_store(g);
        let peak_fused = arena::peak_request();
        assert!(
            peak_fused < rows_by_vocab,
            "fused path requested a {peak_fused}-element buffer (logits would be {rows_by_vocab})"
        );
        // sanity: the unfused chain does request the logits buffer, so the
        // probe genuinely discriminates
        ops::set_fused_xent_override(Some(false));
        arena::reset_stats();
        let (_l2, g2, _m2) = loss_and_grads(&cfg, &params, &batch).unwrap();
        arena::recycle_store(g2);
        assert!(
            arena::peak_request() >= rows_by_vocab,
            "unfused sanity run must materialize the logits"
        );
        ops::set_fused_xent_override(None);
    }

    #[test]
    fn param_shapes_match_testutil_store() {
        // the growth testutil store and the engine must agree on the bert
        // tensor set (they are the same naming scheme by construction)
        let cfg = crate::growth::testutil::mk_cfg(2, 8, 2);
        let store = crate::growth::testutil::small_store(&cfg);
        let shapes = param_shapes(&cfg);
        assert_eq!(shapes.len(), store.len());
        for (name, shape) in &shapes {
            assert_eq!(&store.expect(name).shape, shape, "{name}");
        }
    }
}
