//! Native text-family forward passes (BERT / GPT analogs, plus the
//! sequence-classification probe head), mirroring `python/compile/
//! transformer.py` — pre-LN blocks for both families (see the NOTE in
//! `encode_text` there), the tied `emb_tok` LM head, and the masked mean
//! cross-entropy.

use std::collections::BTreeMap;

use crate::bail;
use crate::config::ModelConfig;
use crate::error::Result;
use crate::tensor::ops::AttnShape;
use crate::tensor::store::Store;

use super::tape::{Tape, Var};
use super::{head_accuracy, var};

/// One pre-LN transformer block on the flattened (batch*s, d) stream.
/// `layerscale` enables the CaiT per-module scales (`ls1`/`ls2`).
pub(super) fn preln_block(
    tape: &mut Tape<'_>,
    vars: &BTreeMap<String, Var>,
    prefix: &str,
    x: Var,
    sh: AttnShape,
    layerscale: bool,
) -> Result<Var> {
    let h = {
        let g = var(vars, &format!("{prefix}ln1_g"))?;
        let b = var(vars, &format!("{prefix}ln1_b"))?;
        tape.layernorm(x, g, b)?
    };
    let q = {
        let w = var(vars, &format!("{prefix}q_w"))?;
        let b = var(vars, &format!("{prefix}q_b"))?;
        tape.linear_bias(h, w, b)?
    };
    let k = {
        let w = var(vars, &format!("{prefix}k_w"))?;
        let b = var(vars, &format!("{prefix}k_b"))?;
        tape.linear_bias(h, w, b)?
    };
    let v = {
        let w = var(vars, &format!("{prefix}v_w"))?;
        let b = var(vars, &format!("{prefix}v_b"))?;
        tape.linear_bias(h, w, b)?
    };
    let att = tape.attention(q, k, v, sh)?;
    let mut o = {
        let w = var(vars, &format!("{prefix}o_w"))?;
        let b = var(vars, &format!("{prefix}o_b"))?;
        tape.linear_bias(att, w, b)?
    };
    if layerscale {
        o = tape.mul_row(o, var(vars, &format!("{prefix}ls1"))?)?;
    }
    let x = tape.add(x, o)?;
    let h2 = {
        let g = var(vars, &format!("{prefix}ln2_g"))?;
        let b = var(vars, &format!("{prefix}ln2_b"))?;
        tape.layernorm(x, g, b)?
    };
    // FFN: fc1 + bias + GELU run as one fused kernel pass
    let a = {
        let w = var(vars, &format!("{prefix}fc1_w"))?;
        let b = var(vars, &format!("{prefix}fc1_b"))?;
        tape.linear_bias_gelu(h2, w, b)?
    };
    let mut f2 = {
        let w = var(vars, &format!("{prefix}fc2_w"))?;
        let b = var(vars, &format!("{prefix}fc2_b"))?;
        tape.linear_bias(a, w, b)?
    };
    if layerscale {
        f2 = tape.mul_row(f2, var(vars, &format!("{prefix}ls2"))?)?;
    }
    tape.add(x, f2)
}

/// BERT/GPT loss (MLM / causal LM via the tied head), or the mean-pool +
/// linear probe head when the config declares `n_classes`. Returns the loss
/// node and the optional accuracy metric.
pub(super) fn text_loss(
    tape: &mut Tape<'_>,
    vars: &BTreeMap<String, Var>,
    cfg: &ModelConfig,
    batch: &Store,
) -> Result<(Var, Option<f32>)> {
    let Some(tokens) = batch.get("tokens") else {
        bail!("text batch for '{}' missing 'tokens'", cfg.name)
    };
    let Some(labels) = batch.get("labels") else {
        bail!("text batch for '{}' missing 'labels'", cfg.name)
    };
    if tokens.shape.len() != 2 {
        bail!("'tokens' must be (batch, seq), got {:?}", tokens.shape);
    }
    let (b, s) = (tokens.shape[0], tokens.shape[1]);
    if s != cfg.seq {
        bail!("batch seq {} != config '{}' seq {}", s, cfg.name, cfg.seq);
    }
    let ids = tokens.i32s().to_vec();
    if let Some(&bad) = ids.iter().find(|&&t| t < 0 || t as usize >= cfg.vocab) {
        bail!("token id {bad} outside vocab {} for '{}'", cfg.vocab, cfg.name);
    }
    let emb_tok = var(vars, "emb_tok")?;
    let x0 = tape.gather(emb_tok, ids)?;
    let pos = var(vars, "emb_pos")?;
    let mut x = tape.add_tiled(x0, pos, b)?;
    let sh = AttnShape {
        batch: b,
        heads: cfg.heads,
        s_q: s,
        s_k: s,
        causal: cfg.family == "gpt",
    };
    for l in 0..cfg.layers {
        x = preln_block(tape, vars, &format!("L{l:02}_"), x, sh, false)?;
    }
    let xf = {
        let g = var(vars, "final_ln_g")?;
        let bb = var(vars, "final_ln_b")?;
        tape.layernorm(x, g, bb)?
    };
    if cfg.n_classes > 0 {
        // sequence-classification probe: mean-pool + streaming fused head
        // (loss and accuracy both run tile-by-tile — no logits tensor)
        if labels.shape != vec![b] {
            bail!("probe labels must be ({b},), got {:?}", labels.shape);
        }
        let pooled = tape.seq_mean(xf, b, s)?;
        let w = var(vars, "head_w")?;
        let bb = var(vars, "head_b")?;
        let lbl = labels.i32s().to_vec();
        if let Some(&bad) = lbl.iter().find(|&&l| l >= cfg.n_classes as i32) {
            bail!("label {bad} outside {} classes for '{}'", cfg.n_classes, cfg.name);
        }
        let acc = head_accuracy(tape.value(pooled), tape.value(w), Some(tape.value(bb)), &lbl);
        let loss = tape.lm_head_xent(pooled, w, Some(bb), lbl)?;
        Ok((loss, Some(acc)))
    } else {
        if labels.shape != tokens.shape {
            bail!("LM labels shape {:?} != tokens {:?}", labels.shape, tokens.shape);
        }
        let lbl = labels.i32s().to_vec();
        if let Some(&bad) = lbl.iter().find(|&&l| l >= cfg.vocab as i32) {
            bail!("label {bad} outside vocab {} for '{}'", cfg.vocab, cfg.name);
        }
        // tied LM head, streamed: the (batch*seq, vocab) logits of
        // `xf @ emb_tok^T + mlm_bias` are never materialized
        let mb = var(vars, "mlm_bias")?;
        let loss = tape.lm_head_xent(xf, emb_tok, Some(mb), lbl)?;
        Ok((loss, None))
    }
}
