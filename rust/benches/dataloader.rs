//! Bench: synthetic-data substrate throughput — corpus sampling, MLM
//! masking, vision rendering, probe construction, and the prefetching
//! loader's overhead vs inline generation.

use ligo::config::{artifacts_dir, Registry};
use ligo::data::batches::{gated_batch, lm_batch, mlm_batch};
use ligo::data::corpus::Corpus;
use ligo::data::downstream::{Probe, ProbeKind, SpanProbe};
use ligo::data::loader::Loader;
use ligo::data::vision::VisionTask;
use ligo::util::bench::bench;
use ligo::util::rng::Rng;

fn main() {
    let reg = Registry::load_or_builtin(&artifacts_dir());
    let bert = reg.model("bert_base").unwrap().clone();
    let gpt = reg.model("gpt_base").unwrap().clone();
    let vit = reg.model("vit_b").unwrap().clone();
    let corpus = Corpus::new(512, 0);
    println!("== dataloader: batch construction throughput ==");
    let tokens = (bert.batch * bert.seq) as f64;
    let s = bench("mlm_batch(bert_base)", 5, 50, || {
        mlm_batch(&corpus, &bert, &mut Rng::new(1))
    });
    s.report_throughput(tokens, "tok");
    bench("lm_batch(gpt_base)", 5, 50, || lm_batch(&corpus, &gpt, &mut Rng::new(1)));
    bench("gated_batch(bert_base)", 5, 50, || {
        gated_batch(&corpus, &bert, &mut Rng::new(1), 0.1, 0.15)
    });
    let sv = bench("vision_batch(vit_b)", 3, 20, || {
        VisionTask::pretrain().batch(&vit, &mut Rng::new(1))
    });
    sv.report_throughput(vit.batch as f64, "img");
    let probe_cfg = reg.model("probe_bert_base").unwrap().clone();
    bench("probe_batch(mnli)", 5, 50, || {
        Probe::new(ProbeKind::Mnli, corpus.clone()).batch(&probe_cfg, &mut Rng::new(1))
    });
    bench("span_batch(v2)", 5, 50, || {
        SpanProbe::v2(corpus.clone()).batch(&probe_cfg, &mut Rng::new(1))
    });
    // prefetching loader vs inline
    let c2 = corpus.clone();
    let b2 = bert.clone();
    let loader = Loader::spawn(
        Box::new(move |s| mlm_batch(&c2, &b2, &mut Rng::new(s as u64))), 8);
    bench("loader.next() [prefetched]", 5, 50, || {
        loader.next().expect("producer thread is alive")
    });
}
