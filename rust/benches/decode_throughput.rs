//! Bench: `ligo serve` decode throughput (tokens/s) vs. concurrent
//! sessions. The headline A/B is 4 sessions decoded one-at-a-time
//! (`decode/sequential[s4]`, a max_sessions=1 scheduler draining the same
//! queue) against the same 4 sessions through one batched step per tick
//! (`decode/batched[s4]`) — continuous batching amortizes the weight
//! stream and the LM-head transpose pack across the batch rows, which is
//! the whole economic argument for the scheduler.
//! `bench_baseline.py decode-gate` reads those two lines and requires the
//! batched run to come in at >= 1.5x (self-calibrating against the
//! sequential line of the same run; self-skipping below 4 CPUs). The
//! scaling section records the EXPERIMENTS.md tokens/s-vs-sessions curve.

use ligo::config::{ModelConfig, Registry};
use ligo::coordinator::serve::{Request, Scheduler, ServeOptions};
use ligo::model::decode::Decoder;
use ligo::model::param_shapes;
use ligo::tensor::store::Store;
use ligo::util::bench::bench;
use ligo::util::rng::Rng;

/// Deterministic mixed-length request set: the same workload every
/// iteration and on every host.
fn requests(cfg: &ModelConfig, n: usize) -> Vec<Request> {
    let mut rng = Rng::new(0xdec0de);
    (0..n)
        .map(|i| {
            let max_new = (cfg.seq / 4).clamp(1, 12);
            let plen = (8 + (i * 5) % 9).min(cfg.seq - max_new).max(1);
            Request {
                id: i as u64,
                prompt: (0..plen).map(|_| rng.below(cfg.vocab) as i32).collect(),
                max_new,
                top_k: 8,
                top_p: 0.95,
                seed: 42 + i as u64,
                deadline_steps: 0,
            }
        })
        .collect()
}

/// Drain `reqs` through a scheduler with the given concurrency; returns
/// the tokens sampled (constant across iterations — asserted).
fn run_workload(dec: &Decoder<'_>, max_sessions: usize, reqs: &[Request]) -> u64 {
    let mut sched =
        Scheduler::new(dec, ServeOptions { max_sessions, page_tokens: 16, max_pages: 0 });
    for r in reqs {
        sched.submit(r.clone()).unwrap();
    }
    sched.run().unwrap();
    assert_eq!(sched.pool().live(), 0, "bench workload leaked pages");
    sched.stats().0
}

fn main() {
    let reg = Registry::builtin();
    let cfg = reg.model("gpt_medium").unwrap().clone();
    let params = Store::det_init(&param_shapes(&cfg), 0);
    let dec = Decoder::new(&cfg, &params).unwrap();

    println!("== decode_throughput: batched vs sequential ({}, 4 sessions) ==", cfg.name);
    let reqs = requests(&cfg, 4);
    let tokens: usize = reqs.iter().map(|r| r.max_new).sum();
    for (label, sessions) in [("sequential", 1usize), ("batched", 4)] {
        let s = bench(&format!("decode/{label}[s4]"), 2, 10, || {
            let got = run_workload(&dec, sessions, &reqs);
            assert_eq!(got, tokens as u64);
            got
        });
        println!("{:<44} {:>10}  {:>12.0} tok/s", "", "", tokens as f64 / s.mean_s);
    }

    println!("\n== decode_throughput: tokens/s vs concurrent sessions ==");
    for n in [1usize, 2, 4, 8] {
        let reqs = requests(&cfg, n);
        let tokens: usize = reqs.iter().map(|r| r.max_new).sum();
        let s = bench(&format!("decode/scaling[s{n}]"), 1, 5, || run_workload(&dec, n, &reqs));
        println!("{:<44} {:>10}  {:>12.0} tok/s", "", "", tokens as f64 / s.mean_s);
    }
}
