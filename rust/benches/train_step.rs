//! Bench: full coordinator train step (grad artifact + AdamW + accounting),
//! split into its components to show where time goes (the §Perf breakdown:
//! backend execute should dominate; coordinator overhead <15%), plus a
//! fused-vs-unfused linear-kernel A/B on the same preset so the SIMD
//! microkernel win is measurable in one process (EXPERIMENTS.md records
//! the per-host numbers), plus a serial-vs-2-worker sharded-step A/B (the
//! LIGO_WORKERS pool; `bench_baseline.py workers-gate` reads those lines).
//! `LIGO_BENCH_WORKERS_ONLY=1` runs only the workers section (CI).

use std::sync::Arc;

use ligo::config::{artifacts_dir, Registry, TrainConfig};
use ligo::coordinator::optim::AdamW;
use ligo::coordinator::parallel::SharedBatchFn;
use ligo::coordinator::trainer::Trainer;
use ligo::data::batches::mlm_batch;
use ligo::data::corpus::Corpus;
use ligo::runtime::Runtime;
use ligo::tensor::store::Store;
use ligo::util::bench::bench;
use ligo::util::rng::Rng;

fn main() {
    let reg = Registry::load_or_builtin(&artifacts_dir());
    let rt = Runtime::cpu(artifacts_dir()).unwrap();
    if rt.backend_name() == "null" {
        eprintln!("no executable backend (build with --features pjrt); skipping");
        return;
    }
    let workers_only = ligo::util::knobs::flag_enabled("LIGO_BENCH_WORKERS_ONLY");
    if workers_only {
        workers_section(&reg, &rt);
        return;
    }
    println!("== train_step: coordinator step decomposition ==");
    for name in ["bert_small", "bert_base", "gpt_base"] {
        let cfg = reg.model(name).unwrap().clone();
        let corpus = Corpus::new(cfg.vocab, 0);
        let exe = rt.load(&format!("grad_{name}")).unwrap();
        let mut params = Store::det_init(&exe.manifest.shapes_of("params"), 0);
        let batch = mlm_batch(&corpus, &cfg, &mut Rng::new(0));
        // component 1: PJRT execute only
        let s_exec = bench(&format!("{name}/pjrt_execute"), 3, 15, || {
            exe.run(&[("params", &params), ("batch", &batch)]).unwrap()
        });
        // component 2: optimizer update only
        let out = exe.run(&[("params", &params), ("batch", &batch)]).unwrap();
        let grads = out.groups.get("grads").unwrap().clone();
        let mut opt = AdamW::new(&params, 0.9, 0.999, 1e-8, 0.01, 1.0);
        let s_opt = bench(&format!("{name}/adamw_update"), 3, 15, || {
            opt.step(&mut params, &grads, 1e-4)
        });
        // full trainer step
        let tc = TrainConfig::bert(100);
        let mut tr = Trainer::new(&rt, &cfg, tc, params.clone()).unwrap();
        let c2 = corpus.clone();
        let cfg2 = cfg.clone();
        let s_full = bench(&format!("{name}/full_train_step"), 3, 15, || {
            tr.train_step(&mut |s| mlm_batch(&c2, &cfg2, &mut Rng::new(s as u64))).unwrap()
        });
        let overhead = 1.0 - s_exec.mean_s / s_full.mean_s;
        println!(
            "{:<44} coordinator overhead: {:.1}% (optimizer {:.1}%)",
            "", overhead * 100.0, s_opt.mean_s / s_full.mean_s * 100.0
        );
    }

    // fused vs unfused linear lowering, same preset, one process — the
    // EXPERIMENTS.md A/B for the SIMD microkernel (LIGO_FUSED equivalent)
    println!("\n== train_step: fused vs unfused linear kernels (bert_base) ==");
    let cfg = reg.model("bert_base").unwrap().clone();
    let corpus = Corpus::new(cfg.vocab, 0);
    let exe = rt.load("grad_bert_base").unwrap();
    let params = Store::det_init(&exe.manifest.shapes_of("params"), 0);
    let mut means = Vec::new();
    for (label, fused) in [("fused", true), ("unfused", false)] {
        ligo::tensor::ops::set_fused_override(Some(fused));
        let tc = TrainConfig::bert(100);
        let mut tr = Trainer::new(&rt, &cfg, tc, params.clone()).unwrap();
        let c2 = corpus.clone();
        let cfg2 = cfg.clone();
        let s = bench(&format!("bert_base/train_step[{label}]"), 2, 10, || {
            tr.train_step(&mut |s| mlm_batch(&c2, &cfg2, &mut Rng::new(s as u64))).unwrap()
        });
        means.push(s.mean_s);
    }
    ligo::tensor::ops::set_fused_override(None);
    println!("{:<44} fused kernel speedup: {:.2}x", "", means[1] / means[0]);

    // streaming fused LM head vs the unfused linear+masked_xent chain on
    // the same full train step — the LIGO_FUSED_XENT A/B (the tied head's
    // (batch*seq, vocab) logits are the step's dominant allocation)
    println!("\n== train_step: streaming vs materialized LM head (bert_base) ==");
    let mut xent_means = Vec::new();
    for (label, fused) in [("xent_fused", true), ("xent_unfused", false)] {
        ligo::tensor::ops::set_fused_xent_override(Some(fused));
        let tc = TrainConfig::bert(100);
        let mut tr = Trainer::new(&rt, &cfg, tc, params.clone()).unwrap();
        let c2 = corpus.clone();
        let cfg2 = cfg.clone();
        let s = bench(&format!("bert_base/train_step[{label}]"), 2, 10, || {
            tr.train_step(&mut |s| mlm_batch(&c2, &cfg2, &mut Rng::new(s as u64))).unwrap()
        });
        xent_means.push(s.mean_s);
    }
    ligo::tensor::ops::set_fused_xent_override(None);
    let xent_ratio = xent_means[1] / xent_means[0];
    println!("{:<44} streaming LM-head speedup: {xent_ratio:.2}x", "");

    workers_section(&reg, &rt);
}

/// Serial vs 2-worker sharded step on the same preset and batch stream —
/// both run the tree-reduced `train_step_sharded` path so the A/B isolates
/// the worker-pool scaling (the two runs are bit-identical by design; only
/// wall clock differs). `grad_accum` must be >= the worker count for the
/// pool to have anything to shard.
fn workers_section(reg: &Registry, rt: &Runtime) {
    println!("\n== train_step: serial vs 2-worker sharded step (bert_base) ==");
    let cfg = reg.model("bert_base").unwrap().clone();
    let corpus = Corpus::new(cfg.vocab, 0);
    let exe = rt.load("grad_bert_base").unwrap();
    let params = Store::det_init(&exe.manifest.shapes_of("params"), 0);
    let tc = TrainConfig { grad_accum: 4, ..TrainConfig::bert(100) };
    let c2 = corpus.clone();
    let cfg2 = cfg.clone();
    let batches: SharedBatchFn =
        Arc::new(move |s| mlm_batch(&c2, &cfg2, &mut Rng::new(s as u64)));
    let mut w_means = Vec::new();
    for workers in [1usize, 2] {
        let mut tr = Trainer::new(rt, &cfg, tc.clone(), params.clone()).unwrap();
        let b = batches.clone();
        let s = bench(&format!("bert_base/train_step[workers{workers}]"), 2, 10, || {
            tr.train_step_sharded(&b, workers).unwrap()
        });
        w_means.push(s.mean_s);
    }
    println!("{:<44} 2-worker speedup: {:.2}x", "", w_means[0] / w_means[1]);
}
