//! Bench: growth-operator application cost (pure rust, parameter-space) and
//! the LiGO apply artifact, per pair. Growth is off the training hot path
//! but bounds how cheaply a framework can restart from a smaller model.

use ligo::config::{artifacts_dir, Registry};
use ligo::growth;
use ligo::runtime::Runtime;
use ligo::tensor::store::Store;
use ligo::util::bench::bench;

fn main() {
    let Ok(rt) = Runtime::cpu(artifacts_dir()) else { return };
    let reg = Registry::load(&artifacts_dir()).unwrap();
    let small = reg.model("bert_small").unwrap().clone();
    let large = reg.model("bert_base").unwrap().clone();
    let exe = rt.load("grad_bert_small").unwrap();
    let params = Store::det_init(&exe.manifest.shapes_of("params"), 0);
    println!("== growth_ops: bert_small -> bert_base ==");
    for name in growth::ALL {
        let op = growth::by_name(name).unwrap();
        bench(&format!("grow/{name}"), 2, 15, || op.grow(&params, &small, &large));
    }
    // LiGO apply through the artifact (the learned-path equivalent)
    let apply = rt.load("ligo_apply_bert_small__bert_base").unwrap();
    let m = ligo::coordinator::growth_manager::ligo_init_store(
        &apply.manifest.shapes_of("ligo"), 0.01, 0);
    bench("grow/ligo_apply_artifact", 2, 15, || {
        apply.run(&[("ligo", &m), ("small", &params)]).unwrap()
    });
}
