//! Bench: growth-operator application cost (pure rust, parameter-space),
//! the native LiGO operator, the streaming-vs-materialized LM-head A/B
//! (the `lm_head/xent_*` lines the CI fused-head gate parses), and — when a
//! PJRT backend is available — the LiGO apply artifact, per pair. Growth is
//! off the training hot path but bounds how cheaply a framework can restart
//! from a smaller model.

use ligo::config::{artifacts_dir, Registry};
use ligo::growth;
use ligo::growth::ligo::Ligo;
use ligo::growth::{GrowthContext, LigoOptions};
use ligo::model::tape::Tape;
use ligo::runtime::{Manifest, Runtime};
use ligo::tensor::ops;
use ligo::tensor::store::Store;
use ligo::tensor::Tensor;
use ligo::util::bench::bench;
use ligo::util::rng::Rng;

/// One LM-head forward + backward through the tape on the bert_base head
/// shape (batch*seq = 512 rows, vocab 512, dim 72) at the standard 15% MLM
/// mask density — `fused` picks the streaming kernel or the materialized
/// linear+masked_xent chain. Returns (loss, grad slots) so the work can't
/// be elided.
fn lm_head_step(fused: bool, x: &Tensor, w: &Tensor, b: &Tensor, labels: &[i32]) -> (f32, usize) {
    ops::set_fused_xent_override(Some(fused));
    let mut tape = Tape::new();
    let xv = tape.param(x);
    let wv = tape.param(w);
    let bv = tape.param(b);
    let loss = tape.lm_head_xent(xv, wv, Some(bv), labels.to_vec()).unwrap();
    let l = tape.value(loss).item();
    let grads = tape.backward(loss);
    ops::set_fused_xent_override(None);
    (l, grads.len())
}

fn main() {
    let reg = Registry::load_or_builtin(&artifacts_dir());
    let small = reg.model("bert_small").unwrap().clone();
    let large = reg.model("bert_base").unwrap().clone();
    // the manifest is plain JSON (no runtime backend needed); on a
    // config-only artifacts dir, fall back to the native tensor set, which
    // uses the same naming scheme and det-init
    let params = match Manifest::load(&artifacts_dir(), "grad_bert_small") {
        Ok(manifest) => Store::det_init(&manifest.shapes_of("params"), 0),
        Err(_) => ligo::growth::testutil::small_store(&small),
    };
    println!("== growth_ops: bert_small -> bert_base ==");
    for name in growth::ALL {
        let op = growth::by_name(name).unwrap();
        bench(&format!("grow/{name}"), 2, 15, || {
            growth::grow_params(op.as_ref(), &params, &small, &large).unwrap()
        });
    }
    // native LiGO: init + surrogate M-learning + apply (no artifacts)
    let native = Ligo { steps: 10, ..Default::default() };
    bench("grow/ligo_native[10 M-steps]", 2, 5, || {
        native.grow_with_loss(&params, &small, &large).0
    });
    // true task-loss M-learning through the native engine (the default
    // no-XLA route, via the unified entry point: batches, no runtime):
    // apply + large fwd/bwd + expansion backprop per step
    let corpus = ligo::data::corpus::Corpus::new(large.vocab, 0);
    let ligo_op = growth::by_name("ligo").unwrap();
    let run_task_native = || {
        let mut mk = |s: usize| {
            let mut rng = ligo::util::rng::Rng::new(s as u64);
            ligo::data::batches::mlm_batch(&corpus, &large, &mut rng)
        };
        let ctx = GrowthContext::new(&params, &small, &large)
            .with_batches(&mut mk)
            .with_opts(LigoOptions { steps: 5, ..Default::default() });
        ligo_op.grow(ctx).unwrap()
    };
    let task_stats = bench("grow/ligo_task_native[5 M-steps]", 1, 3, run_task_native);
    // the same loop with the fused linear kernels lowered away — the A/B
    // line EXPERIMENTS.md pairs with the `LIGO_FUSED=0` env knob.
    // LIGO_BENCH_FAST=1 skips it (the CI calibration run only needs the
    // gate line above).
    if !ligo::util::knobs::is_set("LIGO_BENCH_FAST") {
        ligo::tensor::ops::set_fused_override(Some(false));
        let unfused_stats =
            bench("grow/ligo_task_native[5 M-steps, unfused]", 1, 3, run_task_native);
        ligo::tensor::ops::set_fused_override(None);
        let fused_ratio = unfused_stats.mean_s / task_stats.mean_s;
        println!("{:<44} fused kernel speedup: {fused_ratio:.2}x", "");
    }
    // Streaming fused LM head vs the materialized chain on the bert_base
    // tied-head shape (rows 512 x vocab 512 x dim 72, 15% active labels):
    // the CI gate requires the fused line to come in under 1.25x the
    // unfused one (`scripts/bench_baseline.py lmhead-gate`).
    let (rows, dim, vocab) = (large.batch * large.seq, large.dim, large.vocab);
    let mut hr = Rng::new(7);
    let hx = Tensor::from_f32(
        &[rows, dim],
        (0..rows * dim).map(|_| hr.range_f32(-1.0, 1.0)).collect(),
    );
    let hw = Tensor::from_f32(
        &[vocab, dim],
        (0..vocab * dim).map(|_| hr.range_f32(-0.5, 0.5)).collect(),
    );
    let hb = Tensor::from_f32(&[vocab], (0..vocab).map(|_| hr.range_f32(-0.1, 0.1)).collect());
    let hl: Vec<i32> = (0..rows)
        .map(|_| if hr.coin(0.15) { hr.below(vocab) as i32 } else { -1 })
        .collect();
    let fused_head = bench("lm_head/xent_fused", 3, 15, || {
        lm_head_step(true, &hx, &hw, &hb, &hl)
    });
    let unfused_head = bench("lm_head/xent_unfused", 3, 15, || {
        lm_head_step(false, &hx, &hw, &hb, &hl)
    });
    let head_ratio = unfused_head.mean_s / fused_head.mean_s;
    println!("{:<44} streaming LM-head speedup: {head_ratio:.2}x", "");

    // LiGO apply through the artifact (the pjrt fast path), when executable
    let rt = Runtime::cpu(artifacts_dir()).unwrap();
    match rt.load("ligo_apply_bert_small__bert_base") {
        Ok(apply) => {
            let m = ligo::coordinator::growth_manager::ligo_init_store(
                &apply.manifest.shapes_of("ligo"), 0.01, 0);
            bench("grow/ligo_apply_artifact", 2, 15, || {
                apply.run(&[("ligo", &m), ("small", &params)]).unwrap()
            });
        }
        Err(e) => eprintln!("skipping artifact apply bench: {e}"),
    }
    // Regression gate (EXPERIMENTS.md): LIGO_GROWTH_OPS_BUDGET_S bounds the
    // task-native M-learning bench mean on a calibrated host (an unparsable
    // budget warns once through the knob registry and disables the gate).
    if let Some(max_s) = ligo::util::knobs::f64_env("LIGO_GROWTH_OPS_BUDGET_S") {
        if task_stats.mean_s > max_s {
            eprintln!(
                "REGRESSION: grow/ligo_task_native mean {:.3}s > budget {max_s}s",
                task_stats.mean_s
            );
            std::process::exit(1);
        }
        println!("growth_ops within budget: {:.3}s <= {max_s}s", task_stats.mean_s);
    }
}
