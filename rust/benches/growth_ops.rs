//! Bench: growth-operator application cost (pure rust, parameter-space),
//! the native LiGO operator, and — when a PJRT backend is available — the
//! LiGO apply artifact, per pair. Growth is off the training hot path but
//! bounds how cheaply a framework can restart from a smaller model.

use ligo::config::{artifacts_dir, Registry};
use ligo::growth;
use ligo::growth::ligo::Ligo;
use ligo::growth::GrowthOperator;
use ligo::runtime::{Manifest, Runtime};
use ligo::tensor::store::Store;
use ligo::util::bench::bench;

fn main() {
    let Ok(reg) = Registry::load(&artifacts_dir()) else {
        eprintln!("no artifacts; run `make artifacts`");
        return;
    };
    let small = reg.model("bert_small").unwrap().clone();
    let large = reg.model("bert_base").unwrap().clone();
    // the manifest is plain JSON (no runtime backend needed); on a
    // config-only artifacts dir, fall back to the native tensor set, which
    // uses the same naming scheme and det-init
    let params = match Manifest::load(&artifacts_dir(), "grad_bert_small") {
        Ok(manifest) => Store::det_init(&manifest.shapes_of("params"), 0),
        Err(_) => ligo::growth::testutil::small_store(&small),
    };
    println!("== growth_ops: bert_small -> bert_base ==");
    for name in growth::ALL {
        let op = growth::by_name(name).unwrap();
        bench(&format!("grow/{name}"), 2, 15, || op.grow(&params, &small, &large));
    }
    // native LiGO: init + surrogate M-learning + apply (no artifacts)
    let native = Ligo { steps: 10, ..Default::default() };
    bench("grow/ligo_native[10 M-steps]", 2, 5, || {
        native.grow(&params, &small, &large)
    });
    // LiGO apply through the artifact (the pjrt fast path), when executable
    let rt = Runtime::cpu(artifacts_dir()).unwrap();
    match rt.load("ligo_apply_bert_small__bert_base") {
        Ok(apply) => {
            let m = ligo::coordinator::growth_manager::ligo_init_store(
                &apply.manifest.shapes_of("ligo"), 0.01, 0);
            bench("grow/ligo_apply_artifact", 2, 15, || {
                apply.run(&[("ligo", &m), ("small", &params)]).unwrap()
            });
        }
        Err(e) => eprintln!("skipping artifact apply bench: {e}"),
    }
}
