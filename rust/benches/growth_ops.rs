//! Bench: growth-operator application cost (pure rust, parameter-space),
//! the native LiGO operator, and — when a PJRT backend is available — the
//! LiGO apply artifact, per pair. Growth is off the training hot path but
//! bounds how cheaply a framework can restart from a smaller model.

use ligo::config::{artifacts_dir, Registry};
use ligo::growth;
use ligo::growth::ligo::Ligo;
use ligo::growth::{GrowthContext, LigoOptions};
use ligo::runtime::{Manifest, Runtime};
use ligo::tensor::store::Store;
use ligo::util::bench::bench;

fn main() {
    let reg = Registry::load_or_builtin(&artifacts_dir());
    let small = reg.model("bert_small").unwrap().clone();
    let large = reg.model("bert_base").unwrap().clone();
    // the manifest is plain JSON (no runtime backend needed); on a
    // config-only artifacts dir, fall back to the native tensor set, which
    // uses the same naming scheme and det-init
    let params = match Manifest::load(&artifacts_dir(), "grad_bert_small") {
        Ok(manifest) => Store::det_init(&manifest.shapes_of("params"), 0),
        Err(_) => ligo::growth::testutil::small_store(&small),
    };
    println!("== growth_ops: bert_small -> bert_base ==");
    for name in growth::ALL {
        let op = growth::by_name(name).unwrap();
        bench(&format!("grow/{name}"), 2, 15, || {
            growth::grow_params(op.as_ref(), &params, &small, &large).unwrap()
        });
    }
    // native LiGO: init + surrogate M-learning + apply (no artifacts)
    let native = Ligo { steps: 10, ..Default::default() };
    bench("grow/ligo_native[10 M-steps]", 2, 5, || {
        native.grow_with_loss(&params, &small, &large).0
    });
    // true task-loss M-learning through the native engine (the default
    // no-XLA route, via the unified entry point: batches, no runtime):
    // apply + large fwd/bwd + expansion backprop per step
    let corpus = ligo::data::corpus::Corpus::new(large.vocab, 0);
    let ligo_op = growth::by_name("ligo").unwrap();
    let run_task_native = || {
        let mut mk = |s: usize| {
            let mut rng = ligo::util::rng::Rng::new(s as u64);
            ligo::data::batches::mlm_batch(&corpus, &large, &mut rng)
        };
        let ctx = GrowthContext::new(&params, &small, &large)
            .with_batches(&mut mk)
            .with_opts(LigoOptions { steps: 5, ..Default::default() });
        ligo_op.grow(ctx).unwrap()
    };
    let task_stats = bench("grow/ligo_task_native[5 M-steps]", 1, 3, run_task_native);
    // the same loop with the fused linear kernels lowered away — the A/B
    // line EXPERIMENTS.md pairs with the `LIGO_FUSED=0` env knob.
    // LIGO_BENCH_FAST=1 skips it (the CI calibration run only needs the
    // gate line above).
    if std::env::var("LIGO_BENCH_FAST").is_err() {
        ligo::tensor::ops::set_fused_override(Some(false));
        let unfused_stats =
            bench("grow/ligo_task_native[5 M-steps, unfused]", 1, 3, run_task_native);
        ligo::tensor::ops::set_fused_override(None);
        let fused_ratio = unfused_stats.mean_s / task_stats.mean_s;
        println!("{:<44} fused kernel speedup: {fused_ratio:.2}x", "");
    }
    // LiGO apply through the artifact (the pjrt fast path), when executable
    let rt = Runtime::cpu(artifacts_dir()).unwrap();
    match rt.load("ligo_apply_bert_small__bert_base") {
        Ok(apply) => {
            let m = ligo::coordinator::growth_manager::ligo_init_store(
                &apply.manifest.shapes_of("ligo"), 0.01, 0);
            bench("grow/ligo_apply_artifact", 2, 15, || {
                apply.run(&[("ligo", &m), ("small", &params)]).unwrap()
            });
        }
        Err(e) => eprintln!("skipping artifact apply bench: {e}"),
    }
    // Regression gate (EXPERIMENTS.md): LIGO_GROWTH_OPS_BUDGET_S bounds the
    // task-native M-learning bench mean on a calibrated host.
    if let Ok(budget) = std::env::var("LIGO_GROWTH_OPS_BUDGET_S") {
        match budget.parse::<f64>() {
            Ok(max_s) if task_stats.mean_s > max_s => {
                eprintln!(
                    "REGRESSION: grow/ligo_task_native mean {:.3}s > budget {max_s}s",
                    task_stats.mean_s
                );
                std::process::exit(1);
            }
            Ok(max_s) => {
                println!("growth_ops within budget: {:.3}s <= {max_s}s", task_stats.mean_s)
            }
            Err(e) => eprintln!("ignoring unparsable LIGO_GROWTH_OPS_BUDGET_S: {e}"),
        }
    }
}
