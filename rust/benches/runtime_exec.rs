//! Bench: execute latency for the fwd/grad executables of each family —
//! the L3 hot path (synthesized native engine by default, PJRT with the
//! `pjrt` feature). Reports per-call latency and effective FLOP/s.

use ligo::config::{artifacts_dir, Registry};
use ligo::coordinator::flops::{forward_flops, train_step_flops};
use ligo::data::batches::mlm_batch;
use ligo::data::corpus::Corpus;
use ligo::runtime::Runtime;
use ligo::tensor::store::Store;
use ligo::util::bench::bench;
use ligo::util::rng::Rng;

fn main() {
    let reg = Registry::load_or_builtin(&artifacts_dir());
    let rt = Runtime::cpu(artifacts_dir()).unwrap();
    if rt.backend_name() == "null" {
        eprintln!("no executable backend (build with --features pjrt); skipping");
        return;
    }
    println!("== runtime_exec: {} execute latency per artifact ==", rt.backend_name());
    for name in ["bert_small", "bert_base", "bert_large", "gpt_base", "vit_s"] {
        let cfg = reg.model(name).unwrap().clone();
        let corpus = Corpus::new(cfg.vocab.max(512), 0);
        let batch = if cfg.is_vision() {
            ligo::data::vision::VisionTask::pretrain().batch(&cfg, &mut Rng::new(0))
        } else {
            mlm_batch(&corpus, &cfg, &mut Rng::new(0))
        };
        for kind in ["fwd", "grad"] {
            let exe = rt.load(&format!("{kind}_{name}")).unwrap();
            let params = Store::det_init(&exe.manifest.shapes_of("params"), 0);
            let stats = bench(&format!("{kind}_{name}"), 3, 20, || {
                exe.run(&[("params", &params), ("batch", &batch)]).unwrap()
            });
            let flops = if kind == "fwd" { forward_flops(&cfg) } else { train_step_flops(&cfg) };
            println!(
                "{:<44} {:>10}  {:>10.2} GFLOP/s  ({} B in, {} B out)",
                "", "",
                flops / stats.mean_s / 1e9,
                exe.input_bytes(),
                exe.output_bytes()
            );
        }
    }
}
