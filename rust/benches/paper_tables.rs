//! Bench: one end-to-end micro-run per paper table/figure — the cost of
//! regenerating each result, and a regression guard that the experiment
//! paths stay runnable. (Full reproductions: `ligo experiment <id>`.)

use ligo::config::{artifacts_dir, Registry};
use ligo::experiments;
use ligo::runtime::Runtime;
use ligo::util::bench::fmt_t;
use ligo::util::timer::Timer;

fn main() {
    let Ok(reg) = Registry::load(&artifacts_dir()) else {
        eprintln!("no artifacts; run `make artifacts`");
        return;
    };
    let rt = Runtime::cpu(artifacts_dir()).unwrap();
    if rt.backend_name() == "null" {
        eprintln!("no executable backend (build with --features pjrt); skipping");
        return;
    }
    let out = std::env::temp_dir().join("ligo_bench_tables");
    let _ = std::fs::remove_dir_all(&out);
    println!("== paper_tables: micro-scale end-to-end per table/figure ==");
    // scale 0.04 => ~24-step runs: exercises every code path cheaply.
    // LIGO_BENCH_IDS=fig2,table3 restricts the set (CI time budgets).
    let filter = std::env::var("LIGO_BENCH_IDS").ok();
    let ids: Vec<&str> = match &filter {
        Some(s) => s.split(',').collect(),
        None => experiments::ALL.to_vec(),
    };
    for id in ids {
        let t = Timer::new();
        match experiments::run(&rt, &reg, id, 0.04, &out) {
            Ok(()) => println!(">>> {id}: {}", fmt_t(t.elapsed())),
            Err(e) => {
                eprintln!(">>> {id}: FAILED: {e:#}");
                std::process::exit(1);
            }
        }
    }
}
