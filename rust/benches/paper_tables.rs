//! Bench: one end-to-end micro-run per paper table/figure — the cost of
//! regenerating each result, and a regression guard that the experiment
//! paths stay runnable. (Full reproductions: `ligo experiment <id>`.)

use ligo::config::{artifacts_dir, Registry};
use ligo::experiments;
use ligo::runtime::Runtime;
use ligo::util::bench::fmt_t;
use ligo::util::timer::Timer;

fn main() {
    let reg = Registry::load_or_builtin(&artifacts_dir());
    let rt = Runtime::cpu(artifacts_dir()).unwrap();
    if rt.backend_name() == "null" {
        eprintln!("no executable backend (build with --features pjrt); skipping");
        return;
    }
    // The native backend synthesizes only fwd_*/grad_*: experiments that
    // need kd_grad_*/grad_gated_*/span/adapter artifacts are expected to
    // fail on it and count as skips; on an artifact-executing backend
    // (pjrt) any failure is a regression.
    let partial_backend = rt.backend_name() == "native";
    let out = std::env::temp_dir().join("ligo_bench_tables");
    let _ = std::fs::remove_dir_all(&out);
    println!("== paper_tables: micro-scale end-to-end per table/figure ==");
    // scale 0.04 => ~24-step runs: exercises every code path cheaply.
    // LIGO_BENCH_IDS=fig2,table3 restricts the set (CI time budgets).
    let filter = ligo::util::knobs::raw("LIGO_BENCH_IDS");
    let ids: Vec<&str> = match &filter {
        Some(s) => s.split(',').collect(),
        None => experiments::ALL.to_vec(),
    };
    let mut skipped = 0usize;
    for id in ids {
        let t = Timer::new();
        match experiments::run(&rt, &reg, id, 0.04, &out) {
            Ok(()) => println!(">>> {id}: {}", fmt_t(t.elapsed())),
            Err(e) if partial_backend => {
                eprintln!(">>> {id}: skipped on the native backend: {e:#}");
                skipped += 1;
            }
            Err(e) => {
                eprintln!(">>> {id}: FAILED: {e:#}");
                std::process::exit(1);
            }
        }
    }
    if skipped > 0 {
        eprintln!("({skipped} experiment(s) need AOT artifacts; rerun with --features pjrt)");
    }
}
