//! Offline API stub of [xla-rs](https://github.com/LaurentMazare/xla-rs).
//!
//! Mirrors exactly the slice of the xla-rs surface that `ligo`'s PJRT
//! backend uses, so the `pjrt` feature always *compiles* — even in an
//! offline container with no XLA C libraries. At runtime, client creation
//! fails with a clear "XLA unavailable" error, which the coordinator treats
//! as "fall back to the native backend".
//!
//! To execute real HLO artifacts, replace this directory with an xla-rs
//! checkout (the crate name and API match) and rebuild with
//! `cargo build --release --features pjrt`.

use std::fmt;
use std::path::Path;

/// Error type matching xla-rs's usage patterns (`?`-compatible).
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: XLA is unavailable (the vendored `xla` crate is an offline API stub; \
             swap in a real xla-rs build to execute PJRT artifacts)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types the ligo runtime moves across the PJRT boundary.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        let _ = path.as_ref();
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::unavailable("Literal::reshape"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple"))
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must not create clients");
        assert!(err.to_string().contains("stub"));
    }
}
