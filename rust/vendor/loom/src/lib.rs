//! Offline stand-in for the `loom` model checker (see Cargo.toml).
//!
//! The real loom explores every interleaving of a bounded concurrent
//! program by replacing `std::sync`/`std::thread` with instrumented
//! versions and backtracking over scheduling decisions. This shim keeps
//! the *API contract* — tests written against it run unchanged under the
//! real crate — but implements [`model`] as a stress loop: the closure is
//! re-run many times on OS threads, which in practice surfaces the same
//! ordering bugs probabilistically instead of exhaustively.
//!
//! Only the surface the `ligo` model tests use is provided.

/// Run `f` repeatedly, as the real loom would run it once per explored
/// interleaving. Panics propagate (a failed iteration fails the test).
pub fn model<F>(f: F)
where
    F: Fn() + Sync + Send + 'static,
{
    // enough repeats to shake out ordering-dependent failures in the
    // small (2-3 thread) models the suite runs, cheap enough for CI
    const ITERS: usize = 64;
    for _ in 0..ITERS {
        f();
    }
}

/// `loom::sync` — re-exports of the std primitives the real crate models.
pub mod sync {
    pub use std::sync::{Arc, Condvar, Mutex, MutexGuard};

    /// `loom::sync::atomic` mirror.
    pub mod atomic {
        pub use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
    }
}

/// `loom::thread` — real OS threads with an extra scheduling perturbation
/// point where the real loom would branch.
pub mod thread {
    pub use std::thread::{spawn, JoinHandle};

    /// The real loom treats `yield_now` as an explicit preemption point;
    /// here it nudges the OS scheduler for the same effect.
    pub fn yield_now() {
        std::thread::yield_now();
    }
}

#[cfg(test)]
mod tests {
    use super::sync::{Arc, Mutex};

    #[test]
    fn model_runs_the_closure_and_propagates_state() {
        let hits = Arc::new(Mutex::new(0usize));
        let h = hits.clone();
        super::model(move || {
            *h.lock().unwrap() += 1;
        });
        assert!(*hits.lock().unwrap() >= 2, "model must re-run the closure");
    }

    #[test]
    fn threads_join() {
        let t = super::thread::spawn(|| 21 * 2);
        super::thread::yield_now();
        assert_eq!(t.join().unwrap(), 42);
    }
}
