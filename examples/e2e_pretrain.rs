//! End-to-end driver at realistic scale: grow a ~25M-parameter BERT into a
//! ~91M-parameter BERT with LiGO and pretrain it for a few hundred steps on
//! the synthetic corpus, logging the loss curve — proof that all three
//! layers (Pallas kernels -> JAX graphs -> rust coordinator) compose at
//! ~100M-parameter scale.
//!
//! Results are recorded in EXPERIMENTS.md §E2E.
//!
//! Run: cargo run --release --example e2e_pretrain -- [--steps N] [--small-steps N]
//!      (defaults sized for ~30-40 min on one CPU core)

use ligo::bail;
use ligo::config::{artifacts_dir, Registry};
use ligo::error::Result;
use ligo::coordinator::flops::train_step_flops;
use ligo::coordinator::trainer::Trainer;
use ligo::growth::{self, GrowthContext, LigoOptions};
use ligo::data::batches::mlm_batch;
use ligo::data::corpus::Corpus;
use ligo::data::loader::Loader;
use ligo::experiments::common::recipe_for;
use ligo::runtime::Runtime;
use ligo::util::cli::Args;
use ligo::util::rng::Rng;
use ligo::util::timer::Timer;

fn main() -> Result<()> {
    ligo::util::logging::init_from_env();
    let args = Args::from_env();
    let steps = args.get_usize("steps", 220);
    let small_steps = args.get_usize("small-steps", 60);
    let m_steps = args.get_usize("m-steps", 30);

    let rt = Runtime::cpu(artifacts_dir())?;
    let reg = Registry::load_or_builtin(&artifacts_dir());
    let small = reg.model("e2e_small")?.clone();
    let large = reg.model("e2e_base")?.clone();
    println!(
        "e2e: {} ({:.1}M params) -> {} ({:.1}M params)",
        small.name,
        *reg.param_counts.get(&small.name).unwrap_or(&0) as f64 / 1e6,
        large.name,
        *reg.param_counts.get(&large.name).unwrap_or(&0) as f64 / 1e6,
    );
    let corpus = Corpus::new(small.vocab, 42);

    // Stage 1: briefly pretrain the 25M source model
    println!("\n[stage 1] pretraining {} for {small_steps} steps", small.name);
    let t = Timer::new();
    let params = Trainer::scratch_params(&rt, &small, 0)?;
    let mut tc = recipe_for(&small, small_steps);
    tc.eval_every = 20;
    let mut tr = Trainer::new(&rt, &small, tc, params)?;
    // prefetching loader hides the masking cost behind PJRT execution
    let c1 = corpus.clone();
    let s1 = small.clone();
    let loader = Loader::spawn(
        Box::new(move |step| mlm_batch(&c1, &s1, &mut Rng::new(step as u64))),
        4,
    );
    let mut curve_small = ligo::coordinator::metrics::Curve::new("e2e_small");
    let mut spent = 0.0f64;
    let step_flops = train_step_flops(&small);
    for step in 0..small_steps {
        let Some(batch) = loader.next() else {
            bail!("batch loader stopped early at step {step}");
        };
        let mut one = |_s: usize| batch.clone();
        let loss = tr.train_step(&mut one)?;
        spent += step_flops;
        if step % 20 == 0 || step + 1 == small_steps {
            let el = t.elapsed();
            println!("  step {step:>4}  loss {loss:.4}  ({spent:.2e} FLOPs, {el:.0}s)");
            curve_small.push(step, spent, t.elapsed(), loss, None);
        }
    }
    drop(loader);

    // Stage 2: learn M and grow (the unified entry point; a GrowthPlan run
    // via Trainer::run_plan expresses the same pipeline declaratively —
    // this driver keeps the manual stages to show the prefetching loader)
    println!("\n[stage 2] learning LiGO M for {m_steps} steps and growing");
    let c2 = corpus.clone();
    let l2 = large.clone();
    let mut mk = move |s: usize| mlm_batch(&c2, &l2, &mut Rng::new(0xE2E + s as u64));
    let opts = LigoOptions { steps: m_steps, lr: 0.01, ..Default::default() };
    let ctx = GrowthContext::new(&tr.params, &small, &large)
        .with_runtime(&rt)
        .with_batches(&mut mk)
        .with_opts(opts);
    let grown = growth::by_name("ligo")?.grow(ctx)?;
    println!("  route: {}", grown.route_summary());
    println!(
        "  M-loss {:.4}; growth overhead {:.2e} FLOPs, {:.0}s wall",
        grown.metrics.final_m_loss, grown.metrics.extra_flops, grown.metrics.wall_s
    );

    // Stage 3: pretrain the 91M model from the LiGO init
    println!("\n[stage 3] pretraining {} for {steps} steps from LiGO init", large.name);
    let mut tc = recipe_for(&large, steps);
    tc.eval_every = 20;
    let mut tr2 = Trainer::new(&rt, &large, tc, grown.params)?;
    tr2.flops_offset = grown.metrics.extra_flops;
    let c3 = corpus.clone();
    let l3 = large.clone();
    let loader = Loader::spawn(
        Box::new(move |step| mlm_batch(&c3, &l3, &mut Rng::new(0xBEEF + step as u64))),
        4,
    );
    let mut curve = ligo::coordinator::metrics::Curve::new("e2e_ligo");
    let step_flops = train_step_flops(&large);
    let mut spent = grown.metrics.extra_flops;
    let t2 = Timer::new();
    for step in 0..steps {
        let Some(batch) = loader.next() else {
            bail!("batch loader stopped early at step {step}");
        };
        let mut one = |_s: usize| batch.clone();
        let loss = tr2.train_step(&mut one)?;
        spent += step_flops;
        if step % 10 == 0 || step + 1 == steps {
            println!(
                "  step {step:>4}  loss {loss:.4}  {:.1} s/step  ({:.2e} FLOPs total)",
                t2.elapsed() / (step + 1) as f64,
                spent
            );
            curve.push(step, spent, t2.elapsed(), loss, None);
        }
    }
    let first = curve.loss.first().copied().unwrap_or(f32::NAN);
    let last = curve.final_loss();
    println!("\n==== e2e summary =====================================");
    println!("91M-param model: loss {first:.4} -> {last:.4} over {steps} steps");
    let s_per_step = t2.elapsed() / steps as f64;
    println!("throughput: {s_per_step:.1} s/step, {step_flops:.2e} FLOPs/step");
    ligo::coordinator::metrics::write_report(
        std::path::Path::new("reports"),
        "e2e_pretrain",
        &[curve_small, curve],
    )?;
    println!("loss curves -> reports/e2e_pretrain.json");
    Ok(())
}
