//! Growth-operator zoo tour: grow the same pretrained BERT-Small into
//! BERT-Base with every operator in the zoo (plus LiGO) and compare the
//! *immediate* quality of each initialization — a concrete look at the
//! paper's §3.1 taxonomy and Prop. 1.
//!
//! Run: cargo run --release --example operator_zoo

use ligo::config::{artifacts_dir, Registry};
use ligo::coordinator::growth_manager::{ligo_grow, LigoOptions};
use ligo::coordinator::trainer::{eval_store, Trainer};
use ligo::error::Result;
use ligo::data::batches::mlm_batch;
use ligo::data::corpus::Corpus;
use ligo::experiments::common::{recipe_for, text_batches};
use ligo::growth;
use ligo::runtime::Runtime;
use ligo::util::rng::Rng;

fn main() -> Result<()> {
    ligo::util::logging::init_from_env();
    let rt = Runtime::cpu(artifacts_dir())?;
    let reg = Registry::load_or_builtin(&artifacts_dir());
    let small = reg.model("bert_small")?.clone();
    let large = reg.model("bert_base")?.clone();
    let corpus = Corpus::new(small.vocab, 0);

    println!("pretraining {} (250 steps)...", small.name);
    let params = Trainer::scratch_params(&rt, &small, 0)?;
    let mut tr = Trainer::new(&rt, &small, recipe_for(&small, 250), params)?;
    let mut b = text_batches(&corpus, &small, 1);
    let c = tr.run("small", &mut b, 250)?;
    let small_params = tr.params.clone();
    println!("small model loss: {:.4}\n", c.final_loss());

    let fwd = rt.load(&format!("fwd_{}", large.name))?;
    let c2 = corpus.clone();
    let l2 = large.clone();
    let mut eval = move |i: usize| mlm_batch(&c2, &l2, &mut Rng::new(0xEEAA_0000 + i as u64));

    println!("{:<16} {:>12} {:>14}", "operator", "init loss", "vs scratch");
    let scratch = Trainer::scratch_params(&rt, &large, 5)?;
    let (scratch_loss, _) = eval_store(&fwd, &scratch, &mut eval, 8)?;
    println!("{:<16} {:>12.4} {:>14}", "scratch", scratch_loss, "-");
    for name in growth::ALL {
        let op = growth::by_name(name).unwrap();
        let grown = op.grow(&small_params, &small, &large);
        let (loss, _) = eval_store(&fwd, &grown, &mut eval, 8)?;
        println!("{:<16} {:>12.4} {:>13.1}%", name, loss,
            (1.0 - loss / scratch_loss) * 100.0);
    }
    // the learned operator
    let c3 = corpus.clone();
    let l3 = large.clone();
    let mut mk = move |s: usize| mlm_batch(&c3, &l3, &mut Rng::new(0x700 + s as u64));
    for m_steps in [0usize, 25, 100] {
        let grown = ligo_grow(&rt, &small, &large, &small_params, &mut mk,
            &LigoOptions { steps: m_steps, ..Default::default() })?;
        let (loss, _) = eval_store(&fwd, &grown.params, &mut eval, 8)?;
        println!("{:<16} {:>12.4} {:>13.1}%", format!("ligo@{m_steps}"), loss,
            (1.0 - loss / scratch_loss) * 100.0);
    }
    println!("\n(ligo@0 = the stacking+duplication pattern of Prop. 1; the gap to");
    println!(" ligo@100 is what 100 steps of M-learning buys before training begins)");
    Ok(())
}
