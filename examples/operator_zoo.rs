//! Growth-operator zoo tour through the **unified entry point**: grow the
//! same pretrained BERT-Small into BERT-Base with every registered operator
//! via `grow(GrowthContext)` and compare the *immediate* quality of each
//! initialization — the paper's §3.1 taxonomy and Prop. 1, plus the
//! LEMON-style exact expansion (shown on a pair inside its exact regime,
//! with its loss-preservation printed; on the incompatible pair it reports
//! its diagnostic instead of growing wrong).
//!
//! Run: cargo run --release --example operator_zoo

use ligo::config::{artifacts_dir, Registry};
use ligo::coordinator::trainer::{eval_store, Trainer};
use ligo::error::Result;
use ligo::data::batches::mlm_batch;
use ligo::data::corpus::Corpus;
use ligo::experiments::common::{recipe_for, text_batches};
use ligo::growth::{self, GrowthContext, LigoOptions, Objective};
use ligo::runtime::Runtime;
use ligo::util::rng::Rng;

fn main() -> Result<()> {
    ligo::util::logging::init_from_env();
    let rt = Runtime::cpu(artifacts_dir())?;
    let reg = Registry::load_or_builtin(&artifacts_dir());
    let small = reg.model("bert_small")?.clone();
    let large = reg.model("bert_base")?.clone();
    let corpus = Corpus::new(small.vocab, 0);

    println!("pretraining {} (250 steps)...", small.name);
    let params = Trainer::scratch_params(&rt, &small, 0)?;
    let mut tr = Trainer::new(&rt, &small, recipe_for(&small, 250), params)?;
    let mut b = text_batches(&corpus, &small, 1);
    let c = tr.run("small", &mut b, 250)?;
    let small_params = tr.params.clone();
    println!("small model loss: {:.4}\n", c.final_loss());

    let fwd = rt.load(&format!("fwd_{}", large.name))?;
    let c2 = corpus.clone();
    let l2 = large.clone();
    let mut eval = move |i: usize| mlm_batch(&c2, &l2, &mut Rng::new(0xEEAA_0000 + i as u64));

    println!("{:<16} {:>12} {:>14}", "operator", "init loss", "vs scratch");
    let scratch = Trainer::scratch_params(&rt, &large, 5)?;
    let (scratch_loss, _) = eval_store(&fwd, &scratch, &mut eval, 8)?;
    println!("{:<16} {:>12.4} {:>14}", "scratch", scratch_loss, "-");
    // every registered operator through the same entry point; operators
    // whose exactness constraints reject the pair report why instead
    for name in growth::KNOWN {
        if name == "ligo" {
            continue; // the learned operator gets its own sweep below
        }
        let op = growth::by_name(name)?;
        let ctx = GrowthContext::new(&small_params, &small, &large);
        match op.grow(ctx) {
            Ok(outcome) => {
                let (loss, _) = eval_store(&fwd, &outcome.params, &mut eval, 8)?;
                assert!(loss.is_finite(), "{name}: non-finite init loss");
                println!("{:<16} {:>12.4} {:>13.1}%", name, loss,
                    (1.0 - loss / scratch_loss) * 100.0);
            }
            Err(e) => println!("{name:<16} skipped: {e}"),
        }
    }
    // the learned operator: same context surface, batch source attached
    let c3 = corpus.clone();
    let l3 = large.clone();
    let mut mk = move |s: usize| mlm_batch(&c3, &l3, &mut Rng::new(0x700 + s as u64));
    for m_steps in [0usize, 25, 100] {
        let ctx = GrowthContext::new(&small_params, &small, &large)
            .with_runtime(&rt)
            .with_batches(&mut mk)
            .with_opts(LigoOptions { steps: m_steps, ..Default::default() });
        let grown = growth::by_name("ligo")?.grow(ctx)?;
        assert_ne!(grown.objective, Objective::ParamOnly, "ligo must learn M");
        let (loss, _) = eval_store(&fwd, &grown.params, &mut eval, 8)?;
        println!("{:<16} {:>12.4} {:>13.1}%", format!("ligo@{m_steps}"), loss,
            (1.0 - loss / scratch_loss) * 100.0);
    }
    println!("\n(ligo@0 = the stacking+duplication pattern of Prop. 1; the gap to");
    println!(" ligo@100 is what 100 steps of M-learning buys before training begins)");

    // LEMON on a pair inside its exact regime: depth-only 3 -> 6 layers.
    // The grown model's loss must equal the small model's exactly.
    let mid = reg.model("bert_d6w48")?.clone();
    let lemon = growth::by_name("lemon")?;
    let exact = lemon.grow(GrowthContext::new(&small_params, &small, &mid))?;
    let fwd_mid = rt.load(&format!("fwd_{}", mid.name))?;
    let c4 = corpus.clone();
    let m4 = mid.clone();
    let mut eval_mid = move |i: usize| mlm_batch(&c4, &m4, &mut Rng::new(0xEEAA_0000 + i as u64));
    let fwd_small = rt.load(&format!("fwd_{}", small.name))?;
    let (l_small, _) = eval_store(&fwd_small, &small_params, &mut eval_mid, 8)?;
    let (l_lemon, _) = eval_store(&fwd_mid, &exact.params, &mut eval_mid, 8)?;
    println!(
        "\nlemon {} -> {}: small loss {l_small:.6}, grown loss {l_lemon:.6} \
         (diff {:.2e} — lossless)",
        small.name,
        mid.name,
        (l_small - l_lemon).abs()
    );
    assert!(
        (l_small - l_lemon).abs() <= 1e-4,
        "lemon must preserve the loss: {l_small} vs {l_lemon}"
    );
    Ok(())
}
