//! Quickstart: the LiGO pipeline end to end in ~a minute on one CPU core.
//!
//! 1. pretrain a small BERT on the synthetic corpus,
//! 2. learn the LiGO growth operator M with 100 SGD steps,
//! 3. initialize BERT-Base as M(Theta_small) and keep training,
//! 4. compare against training BERT-Base from scratch and report the
//!    FLOPs savings (the paper's headline number).
//!
//! Run: cargo run --release --example quickstart

use ligo::config::{artifacts_dir, Registry};
use ligo::coordinator::metrics::savings;
use ligo::error::Result;
use ligo::coordinator::trainer::Trainer;
use ligo::data::batches::mlm_batch;
use ligo::data::corpus::Corpus;
use ligo::experiments::common::{recipe_for, text_batches};
use ligo::growth::{self, GrowthContext, LigoOptions};
use ligo::runtime::Runtime;
use ligo::util::rng::Rng;

fn main() -> Result<()> {
    ligo::util::logging::init_from_env();
    let rt = Runtime::cpu(artifacts_dir())?;
    let reg = Registry::load_or_builtin(&artifacts_dir());
    println!("platform: {}", rt.platform());

    let small = reg.model("bert_small")?.clone();
    let large = reg.model("bert_base")?.clone();
    let corpus = Corpus::new(small.vocab, 0);

    // --- 1. pretrain the small model -------------------------------------
    println!("\n[1/4] pretraining {} ({} params)...", small.name,
        reg.param_counts.get(&small.name).unwrap_or(&0));
    let params = Trainer::scratch_params(&rt, &small, 0)?;
    let mut tr_small = Trainer::new(&rt, &small, recipe_for(&small, 150), params)?;
    let mut b_small = text_batches(&corpus, &small, 1);
    let c_small = tr_small.run("small", &mut b_small, 150)?;
    println!("      small loss: {:.3} -> {:.3}", c_small.loss[0], c_small.final_loss());

    // --- 2. learn the growth operator M (the paper's 100 steps) ----------
    // One unified entry point: the context offers the runtime handle and a
    // batch source; LiGO negotiates artifact -> native task loss ->
    // surrogate from that, exactly once, and logs the route it took.
    println!("\n[2/4] learning LiGO operator M (100 SGD steps)...");
    let c2 = corpus.clone();
    let l2 = large.clone();
    let mut mk = move |s: usize| mlm_batch(&c2, &l2, &mut Rng::new(500 + s as u64));
    let ctx = GrowthContext::new(&tr_small.params, &small, &large)
        .with_runtime(&rt)
        .with_batches(&mut mk)
        .with_opts(LigoOptions::default());
    let grown = growth::by_name("ligo")?.grow(ctx)?;
    println!("      route: {}", grown.route_summary());
    println!(
        "      M-loss {:.3} ({} objective), +{:.2e} FLOPs overhead",
        grown.metrics.final_m_loss, grown.objective, grown.metrics.extra_flops
    );

    // --- 3. train the grown large model ----------------------------------
    println!("\n[3/4] training {} from LiGO init...", large.name);
    let steps = 250;
    let mut tr_ligo = Trainer::new(&rt, &large, recipe_for(&large, steps), grown.params)?;
    tr_ligo.flops_offset = grown.metrics.extra_flops;
    let mut b1 = text_batches(&corpus, &large, 2);
    let mut curve_ligo = tr_ligo.run("LiGO", &mut b1, steps)?;
    curve_ligo.name = "LiGO".into();

    // --- 4. baseline: train from scratch ----------------------------------
    println!("\n[4/4] training {} from scratch...", large.name);
    let scratch = Trainer::scratch_params(&rt, &large, 9)?;
    let mut tr_scr = Trainer::new(&rt, &large, recipe_for(&large, steps), scratch)?;
    let mut b2 = text_batches(&corpus, &large, 2);
    let mut curve_scr = tr_scr.run("Scratch", &mut b2, steps)?;
    curve_scr.name = "Scratch".into();

    println!("\n==== results =========================================");
    println!("scratch final loss: {:.4}", curve_scr.final_loss());
    println!("LiGO    final loss: {:.4}", curve_ligo.final_loss());
    match savings(&curve_scr, &curve_ligo, false, false) {
        Some(s) => println!(
            "FLOPs savings to reach scratch-final loss: {:+.1}%  (paper: +44.7%)",
            s * 100.0
        ),
        None => println!("LiGO did not reach the scratch loss in this short run"),
    }
    ligo::coordinator::metrics::write_report(
        std::path::Path::new("reports"),
        "quickstart",
        &[curve_scr, curve_ligo],
    )?;
    println!("curves written to reports/quickstart.json");
    Ok(())
}
