//! Vision-transformer growth (the paper's DeiT-S -> DeiT-B scenario, Fig. 4)
//! on the procedural-shapes ImageNet analog: pretrain ViT-S, grow to ViT-B
//! with both bert2BERT (AKI) and LiGO, and compare accuracy-vs-FLOPs.
//!
//! Run: cargo run --release --example vision_growth -- [--steps N]

use ligo::config::{artifacts_dir, Registry};
use ligo::coordinator::metrics::savings;
use ligo::error::Result;
use ligo::coordinator::trainer::Trainer;
use ligo::data::vision::VisionTask;
use ligo::experiments::common::{recipe_for, vision_batches};
use ligo::growth::{self, GrowthContext, LigoOptions};
use ligo::runtime::Runtime;
use ligo::util::cli::Args;
use ligo::util::rng::Rng;

fn main() -> Result<()> {
    ligo::util::logging::init_from_env();
    let args = Args::from_env();
    let steps = args.get_usize("steps", 300);
    let pre = args.get_usize("pre", 200);

    let rt = Runtime::cpu(artifacts_dir())?;
    let reg = Registry::load_or_builtin(&artifacts_dir());
    let small = reg.model("vit_s")?.clone();
    let large = reg.model("vit_b")?.clone();
    let task = VisionTask::pretrain();

    println!("[1/3] pretraining {} on the shapes dataset ({pre} steps)", small.name);
    let params = Trainer::scratch_params(&rt, &small, 0)?;
    let mut tr = Trainer::new(&rt, &small, recipe_for(&small, pre), params)?;
    let mut b = vision_batches(&task, &small, 3);
    let c = tr.run("vit_s", &mut b, pre)?;
    println!("    acc {:.3} -> {:.3}", c.metric[0], c.final_metric().unwrap());
    let small_params = tr.params.clone();

    println!("[2/3] growing to {} via AKI and LiGO", large.name);
    let aki_op = growth::by_name("aki")?;
    let aki = growth::grow_params(aki_op.as_ref(), &small_params, &small, &large)?;
    let t2 = task.clone();
    let l2 = large.clone();
    let mut mk = move |s: usize| t2.batch(&l2, &mut Rng::new(0xCAFE + s as u64));
    let ctx = GrowthContext::new(&small_params, &small, &large)
        .with_runtime(&rt)
        .with_batches(&mut mk)
        .with_opts(LigoOptions { steps: 30, ..Default::default() });
    let grown = growth::by_name("ligo")?.grow(ctx)?;
    println!("    LiGO route: {}", grown.route_summary());

    println!("[3/3] training {} from scratch / AKI / LiGO ({steps} steps each)", large.name);
    let mut curves = Vec::new();
    for (name, init, offset) in [
        ("Scratch", Trainer::scratch_params(&rt, &large, 5)?, 0.0),
        ("bert2BERT", aki, 0.0),
        ("LiGO", grown.params, grown.metrics.extra_flops),
    ] {
        let mut tr = Trainer::new(&rt, &large, recipe_for(&large, steps), init)?;
        tr.flops_offset = offset;
        let mut b = vision_batches(&task, &large, 8);
        let mut curve = tr.run(name, &mut b, steps)?;
        curve.name = name.to_string();
        println!("    {name:<10} start acc {:.3} final acc {:.3}",
            curve.metric[0], curve.final_metric().unwrap());
        curves.push(curve);
    }
    let scratch = curves[0].clone();
    for c in &curves[1..] {
        if let Some(s) = savings(&scratch, c, false, true) {
            println!("{:<10} FLOPs savings at scratch-final accuracy: {:+.1}% (paper LiGO: +55.4%)",
                c.name, s * 100.0);
        }
    }
    ligo::coordinator::metrics::write_report(
        std::path::Path::new("reports"), "vision_growth", &curves)?;
    Ok(())
}
