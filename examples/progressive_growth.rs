//! Progressive growth as data: a 2-stage `GrowthPlan` executed *mid-run*
//! by `Trainer::run_plan` — start on BERT-Small, stack to 6 layers at 1/3
//! of the budget (StackBERT), then LiGO-grow the width to BERT-Base at 2/3,
//! all against a from-scratch BERT-Base baseline. The schedule is declared
//! once, validated by the builder, and the growth steps land in the curve's
//! `marks` (and the JSON report) — the "Stacking Your Transformers"
//! (Du et al. 2024) scenario the unified growth API was cut for.
//!
//! Run: cargo run --release --example progressive_growth -- [--steps N]

use ligo::config::{artifacts_dir, Registry};
use ligo::coordinator::metrics::savings;
use ligo::coordinator::plan::GrowthPlan;
use ligo::coordinator::trainer::Trainer;
use ligo::error::Result;
use ligo::experiments::common::{recipe_for, text_batches};
use ligo::data::corpus::Corpus;
use ligo::growth::LigoOptions;
use ligo::runtime::Runtime;
use ligo::util::cli::Args;

fn main() -> Result<()> {
    ligo::util::logging::init_from_env();
    let args = Args::from_env();
    let steps = args.get_usize("steps", 240);

    let rt = Runtime::cpu(artifacts_dir())?;
    let reg = Registry::load_or_builtin(&artifacts_dir());
    let small = reg.model("bert_small")?.clone();
    let mid = reg.model("bert_d6w48")?.clone();
    let large = reg.model("bert_base")?.clone();
    let corpus = Corpus::new(large.vocab, 0);

    // the schedule: depth first (cheap stacking), then learned width growth
    let plan = GrowthPlan::builder(&small)
        .grow_at(steps / 3, &mid, "stackbert")
        .grow_at_with(
            2 * steps / 3,
            &large,
            "ligo",
            LigoOptions { steps: 25, ..Default::default() },
        )
        .build()?;
    println!(
        "plan: {} -> {} @{} -> {} @{} ({} stages)",
        small.name,
        mid.name,
        steps / 3,
        large.name,
        2 * steps / 3,
        plan.stages().len()
    );

    println!("\n[1/2] progressive run ({} total steps)", steps);
    let params = Trainer::scratch_params(&rt, &small, 0)?;
    let mut tr = Trainer::new(&rt, &small, recipe_for(&small, steps), params)?;
    let mut b = text_batches(&corpus, &small, 7);
    let curve_plan = tr.run_plan(&rt, "Progressive", &mut b, steps, &plan)?;
    for (step, label) in &curve_plan.marks {
        println!("    @{step}: {label}");
    }
    println!(
        "    final model: {} ({} params), loss {:.4}",
        tr.cfg.name,
        tr.params.param_count(),
        curve_plan.final_loss()
    );

    println!("\n[2/2] scratch {} baseline ({} steps)", large.name, steps);
    let scratch = Trainer::scratch_params(&rt, &large, 5)?;
    let mut tr2 = Trainer::new(&rt, &large, recipe_for(&large, steps), scratch)?;
    let mut b2 = text_batches(&corpus, &large, 8);
    let curve_scr = tr2.run("Scratch", &mut b2, steps)?;

    println!("\n==== results =========================================");
    println!("scratch     final loss: {:.4}", curve_scr.final_loss());
    println!("progressive final loss: {:.4}", curve_plan.final_loss());
    match savings(&curve_scr, &curve_plan, false, false) {
        Some(s) => println!("FLOPs savings to reach scratch-final loss: {:+.1}%", s * 100.0),
        None => println!("progressive run did not reach the scratch loss in this budget"),
    }
    ligo::coordinator::metrics::write_report(
        std::path::Path::new("reports"),
        "progressive_growth",
        &[curve_scr, curve_plan],
    )?;
    println!("curves (incl. growth marks) -> reports/progressive_growth.json");
    Ok(())
}
