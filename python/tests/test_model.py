"""L2 model correctness: shapes, losses, masking semantics, family paths."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import transformer as T
from compile.configs import REGISTRY
from compile.model import batch_specs, param_shapes


def mk_params(name, **kw):
    cfg = REGISTRY[name]
    return cfg, T.init_params(jax.random.PRNGKey(0), cfg, **kw)


def rand_batch(cfg, key=0):
    rng = np.random.RandomState(key)
    specs = batch_specs(cfg)
    out = {}
    for k, s in specs.items():
        if np.dtype(s.dtype) == np.int32:
            hi = cfg.vocab if k == "tokens" else max(cfg.n_classes, 2)
            if k in ("starts", "ends"):
                hi = cfg.seq
            out[k] = rng.randint(0, hi, s.shape).astype(np.int32)
        else:
            out[k] = rng.randn(*s.shape).astype(np.float32) * 0.5
    return out


class TestParamNaming:
    def test_bert_small_has_expected_keys(self):
        cfg, p = mk_params("bert_small")
        assert "emb_tok" in p and "mlm_bias" in p
        for l in range(cfg.layers):
            for suf in ("q_w", "k_w", "v_w", "o_w", "fc1_w", "fc2_w", "ln1_g", "ln2_b"):
                assert f"L{l:02d}_{suf}" in p

    def test_weight_convention_out_in(self):
        cfg, p = mk_params("bert_small")
        assert p["L00_fc1_w"].shape == (cfg.ffn, cfg.dim)
        assert p["L00_fc2_w"].shape == (cfg.dim, cfg.ffn)
        assert p["emb_tok"].shape == (cfg.vocab, cfg.dim)

    def test_shapes_match_param_shapes_helper(self):
        cfg, p = mk_params("gpt_base")
        shapes = param_shapes(cfg)
        assert set(shapes) == set(p)
        for k in p:
            assert shapes[k] == p[k].shape

    def test_cait_has_layerscale_and_cls_layers(self):
        cfg, p = mk_params("cait_xs")
        assert "L00_ls1" in p and "L05_ls2" in p
        assert "C00_q_w" in p and "C01_fc2_w" in p

    def test_adapters_and_span(self):
        cfg, p = mk_params("probe_bert_base", with_adapters=True, with_span=True)
        assert "L00_ad1_w" in p and p["L00_ad1_w"].shape == (T.ADAPTER_DIM, cfg.dim)
        assert p["span_w"].shape == (2, cfg.dim)
        assert p["head_w"].shape == (cfg.n_classes, cfg.dim)


class TestLosses:
    def test_mlm_loss_ignores_negative_labels(self):
        cfg, p = mk_params("bert_small")
        b = rand_batch(cfg)
        all_ignored = dict(b, labels=np.full_like(b["labels"], -1))
        loss = T.lm_loss(p, {k: jnp.array(v) for k, v in all_ignored.items()}, cfg)
        assert float(loss) == 0.0

    def test_mlm_loss_near_uniform_at_init(self):
        cfg, p = mk_params("bert_small")
        b = {k: jnp.array(v) for k, v in rand_batch(cfg).items()}
        b["labels"] = jnp.where(b["labels"] % 3 == 0, b["tokens"], -1)
        loss = float(T.lm_loss(p, b, cfg))
        assert abs(loss - np.log(cfg.vocab)) < 0.5

    def test_gpt_causal_masking_no_future_leak(self):
        """Changing a future token must not change earlier positions' logits."""
        cfg, p = mk_params("gpt_base")
        toks = np.full((1, cfg.seq), 10, np.int32)
        h1 = T.encode_text(p, jnp.array(toks), cfg)
        toks2 = toks.copy()
        toks2[0, -1] = 99
        h2 = T.encode_text(p, jnp.array(toks2), cfg)
        np.testing.assert_allclose(h1[0, : cfg.seq - 1], h2[0, : cfg.seq - 1], atol=1e-5)

    def test_bert_bidirectional_context_leaks(self):
        """BERT (non-causal) SHOULD see future tokens."""
        cfg, p = mk_params("bert_small")
        toks = np.full((1, cfg.seq), 10, np.int32)
        h1 = T.encode_text(p, jnp.array(toks), cfg)
        toks2 = toks.copy()
        toks2[0, -1] = 99
        h2 = T.encode_text(p, jnp.array(toks2), cfg)
        assert not np.allclose(h1[0, 0], h2[0, 0], atol=1e-6)

    def test_vision_loss_and_acc(self):
        cfg, p = mk_params("vit_s")
        b = {k: jnp.array(v) for k, v in rand_batch(cfg).items()}
        loss, acc = T.vision_loss(p, b, cfg)
        assert np.isfinite(float(loss))
        assert 0.0 <= float(acc) <= 1.0
        assert abs(float(loss) - np.log(cfg.n_classes)) < 1.0

    def test_cait_forward_runs(self):
        cfg, p = mk_params("cait_xs")
        b = {k: jnp.array(v) for k, v in rand_batch(cfg).items()}
        loss, acc = T.vision_loss(p, b, cfg)
        assert np.isfinite(float(loss))

    def test_probe_loss(self):
        cfg, p = mk_params("probe_bert_base")
        b = {k: jnp.array(v) for k, v in rand_batch(cfg).items()}
        loss, acc = T.probe_loss(p, b, cfg)
        assert np.isfinite(float(loss)) and 0 <= float(acc) <= 1

    def test_span_loss(self):
        cfg = REGISTRY["probe_bert_base"]
        p = T.init_params(jax.random.PRNGKey(0), cfg, with_span=True)
        rng = np.random.RandomState(0)
        b = {
            "tokens": jnp.array(rng.randint(0, cfg.vocab, (cfg.batch, cfg.seq)), jnp.int32),
            "starts": jnp.array(rng.randint(0, cfg.seq, (cfg.batch,)), jnp.int32),
            "ends": jnp.array(rng.randint(0, cfg.seq, (cfg.batch,)), jnp.int32),
        }
        loss, em = T.span_loss(p, b, cfg)
        assert np.isfinite(float(loss))

    def test_kd_loss_between_sizes(self):
        cfg_s, ps = mk_params("bert_small")
        cfg_l, pl = mk_params("bert_base")
        b = {k: jnp.array(v) for k, v in rand_batch(cfg_l).items()}
        b["labels"] = jnp.where(b["labels"] % 3 == 0, b["tokens"], -1)
        loss = T.kd_loss(ps, pl, b, cfg_s, cfg_l)
        assert np.isfinite(float(loss))


class TestGating:
    def test_zero_gates_reduce_to_embedding_readout(self):
        """With all layer gates 0, the body is an identity + final LN."""
        cfg, p = mk_params("bert_small")
        toks = jnp.array(np.random.RandomState(0).randint(4, 512, (2, cfg.seq)), jnp.int32)
        gates0 = jnp.zeros((cfg.layers,))
        gates1 = jnp.ones((cfg.layers,))
        h0 = T.encode_text(p, toks, cfg, gates=gates0)
        h1 = T.encode_text(p, toks, cfg, gates=gates1)
        emb = p["emb_tok"][toks] + p["emb_pos"][: cfg.seq]
        want = T.layer_norm(emb, p["final_ln_g"], p["final_ln_b"])
        np.testing.assert_allclose(h0, want, atol=1e-5)
        assert not np.allclose(h0, h1, atol=1e-4)

    def test_token_keep_masks_middle_layers(self):
        cfg, p = mk_params("bert_base")  # 6 layers -> middle third is 2..4
        toks = jnp.array(np.random.RandomState(0).randint(4, 512, (2, cfg.seq)), jnp.int32)
        keep_all = jnp.ones((2, cfg.seq))
        keep_none = jnp.zeros((2, cfg.seq))
        h1 = T.encode_text(p, toks, cfg, token_keep=keep_all)
        h2 = T.encode_text(p, toks, cfg, token_keep=keep_none)
        assert not np.allclose(h1, h2, atol=1e-5)


class TestPatchify:
    def test_patchify_shapes_and_content(self):
        img = jnp.arange(2 * 8 * 8 * 3, dtype=jnp.float32).reshape(2, 8, 8, 3)
        p = T._patchify(img, 4)
        assert p.shape == (2, 4, 48)
        # first patch of first image = top-left 4x4 block
        want = np.asarray(img[0, :4, :4, :]).reshape(-1)
        np.testing.assert_array_equal(np.asarray(p[0, 0]), want)
