"""AOT pipeline consistency: registry completeness, manifest flattening
order (the contract with the rust runtime), and HLO text production."""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot
from compile import model as M
from compile.configs import PAIRS, REGISTRY, param_count, to_json


class TestRegistry:
    def test_every_model_has_fwd_and_grad(self):
        arts = M.artifact_registry()
        for name in REGISTRY:
            assert f"fwd_{name}" in arts
            assert f"grad_{name}" in arts

    def test_every_pair_has_ligo_artifacts(self):
        arts = M.artifact_registry()
        for s, t in PAIRS:
            assert f"ligo_grad_{s}__{t}" in arts
            assert f"ligo_apply_{s}__{t}" in arts

    def test_param_count_matches_actual(self):
        for name in ("bert_small", "gpt_base", "vit_s", "cait_xs"):
            cfg = REGISTRY[name]
            shapes = M.param_shapes(cfg)
            actual = sum(int(np.prod(s)) for s in shapes.values())
            assert param_count(cfg) == actual, name

    def test_e2e_base_is_about_100m(self):
        assert 60e6 < param_count(REGISTRY["e2e_base"]) < 150e6

    def test_config_json_complete(self):
        j = to_json()
        assert set(j["models"]) == set(REGISTRY)
        assert j["pairs"] == [list(p) for p in PAIRS] or j["pairs"] == PAIRS


class TestManifestOrdering:
    def test_flat_entries_sorted_by_key(self):
        specs = (
            {"b": jax.ShapeDtypeStruct((2,), np.float32),
             "a": jax.ShapeDtypeStruct((3,), np.float32)},
            {"z": jax.ShapeDtypeStruct((1,), np.int32)},
        )
        entries = aot._flat_entries(specs, ("params", "batch"))
        names = [e["name"] for e in entries]
        assert names == ["params/a", "params/b", "batch/z"]

    def test_flatten_order_matches_jax(self):
        """The manifest order must equal jax.jit's pytree flattening order."""
        fn, specs = M.build("fwd_bert_small")
        flat, _ = jax.tree_util.tree_flatten(specs)
        entries = aot._flat_entries(specs, ("params", "batch"))
        assert len(flat) == len(entries)
        for leaf, e in zip(flat, entries):
            assert list(leaf.shape) == e["shape"], e["name"]

    def test_kind_dispatch(self):
        assert aot._kind("fwd_bert_small") == "fwd"
        assert aot._kind("grad_gated_bert_base") == "grad_gated"
        assert aot._kind("ligo_apply_a__b") == "ligo_apply"
        assert aot._kind("adapter_grad_bert_base") == "adapter_grad"
        with pytest.raises(ValueError):
            aot._kind("bogus_thing")


class TestLowering:
    def test_small_artifact_lowers_to_hlo_text(self):
        fn, specs = M.build("fwd_bert_small")
        lowered = jax.jit(fn, keep_unused=True).lower(*specs)
        text = aot.to_hlo_text(lowered)
        assert "HloModule" in text
        assert "f32" in text

    def test_built_manifests_match_current_source(self, tmp_path=None):
        """If artifacts exist, their manifests must parse and cover the
        declared inputs/outputs."""
        art_dir = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
        man = os.path.join(art_dir, "fwd_bert_small.manifest.json")
        if not os.path.exists(man):
            pytest.skip("artifacts not built")
        with open(man) as f:
            m = json.load(f)
        names = [e["name"] for e in m["inputs"]]
        assert "params/emb_tok" in names
        assert "batch/tokens" in names
        assert m["outputs"][0]["name"] == "loss"
        # count matches the current model definition
        shapes = M.param_shapes(REGISTRY["bert_small"])
        assert len([n for n in names if n.startswith("params/")]) == len(shapes)
