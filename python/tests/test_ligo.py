"""LiGO operator correctness: the tying scheme (App. B.1), Prop. 1 special
cases, linearity, and differentiability."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import transformer as T
from compile.configs import REGISTRY
from compile.ligo import ligo_apply, ligo_init
from compile.model import ligo_specs, param_shapes


def setup(pair=("bert_small", "bert_base")):
    small, large = REGISTRY[pair[0]], REGISTRY[pair[1]]
    sp = T.init_params(jax.random.PRNGKey(1), small)
    lp = ligo_init(jax.random.PRNGKey(2), small, large)
    return small, large, sp, lp


class TestShapes:
    def test_apply_produces_large_shapes(self):
        small, large, sp, lp = setup()
        grown = ligo_apply(lp, sp, small, large)
        want = param_shapes(large)
        assert set(grown) == set(want)
        for k, s in want.items():
            assert grown[k].shape == s, k

    def test_vision_pair(self):
        small, large, sp, lp = (None,) * 4
        s, l = REGISTRY["vit_s"], REGISTRY["vit_b"]
        sp = T.init_params(jax.random.PRNGKey(1), s)
        lp = ligo_init(jax.random.PRNGKey(2), s, l)
        grown = ligo_apply(lp, sp, s, l)
        want = param_shapes(l)
        assert set(grown) == set(want)
        for k, v in want.items():
            assert grown[k].shape == v, k

    def test_cait_pair_includes_cls_layers(self):
        s, l = REGISTRY["cait_xs"], REGISTRY["cait_s"]
        sp = T.init_params(jax.random.PRNGKey(1), s)
        lp = ligo_init(jax.random.PRNGKey(2), s, l)
        grown = ligo_apply(lp, sp, s, l)
        assert grown["C01_q_w"].shape == (l.dim, l.dim)
        assert grown["L00_ls1"].shape == (l.dim,)

    def test_depth_only_pair_has_no_width_params(self):
        s, l = REGISTRY["bert_d3w72"], REGISTRY["bert_base"]
        lp = ligo_init(jax.random.PRNGKey(0), s, l)
        assert not any(k.startswith("B_") for k in lp)
        assert "w_q" in lp and lp["w_q"].shape == (l.layers, s.layers)

    def test_width_only_pair_has_no_depth_params(self):
        s, l = REGISTRY["bert_d6w48"], REGISTRY["bert_base"]
        lp = ligo_init(jax.random.PRNGKey(0), s, l)
        assert not any(k.startswith("w_") for k in lp)
        assert lp["B_emb"].shape == (l.dim, s.dim)

    def test_ligo_specs_match_init(self):
        s, l = REGISTRY["bert_small"], REGISTRY["bert_large"]
        specs = ligo_specs(s, l)
        init = ligo_init(jax.random.PRNGKey(0), s, l)
        assert set(specs) == set(init)


class TestProp1SpecialCases:
    def test_stackbert_is_special_case(self):
        """With w = stacking pattern and B = I (D1 == D2), M(Theta) must
        equal layer duplication exactly (Prop. 1)."""
        s, l = REGISTRY["bert_d3w72"], REGISTRY["bert_base"]  # depth-only
        sp = T.init_params(jax.random.PRNGKey(1), s)
        lp = ligo_init(jax.random.PRNGKey(0), s, l)
        # remove the init noise -> pure stacking pattern
        lp = {k: jnp.round(v) for k, v in lp.items()}
        grown = ligo_apply(lp, sp, s, l)
        for i in range(l.layers):
            src = i % s.layers
            np.testing.assert_allclose(
                grown[f"L{i:02d}_q_w"], sp[f"L{src:02d}_q_w"], atol=1e-5
            )
            np.testing.assert_allclose(
                grown[f"L{i:02d}_fc1_b"], sp[f"L{src:02d}_fc1_b"], atol=1e-5
            )

    def test_neuron_duplication_is_special_case(self):
        """With B = cyclic duplication and no depth growth, rows/cols of the
        grown matrices are copies of small rows/cols (Net2Net pattern,
        without the normalization term which M can learn)."""
        s, l = REGISTRY["bert_d6w48"], REGISTRY["bert_base"]  # width-only
        sp = T.init_params(jax.random.PRNGKey(1), s)
        lp = ligo_init(jax.random.PRNGKey(0), s, l)
        lp = {k: jnp.round(v) for k, v in lp.items()}
        grown = ligo_apply(lp, sp, s, l)
        q = np.asarray(grown["L00_q_w"])
        qs = np.asarray(sp["L00_q_w"])
        d1 = s.dim
        # row j >= d1 equals row (j mod d1); same for columns
        np.testing.assert_allclose(q[d1:, :d1], qs[: l.dim - d1, :], atol=1e-5)
        np.testing.assert_allclose(q[:d1, d1:], qs[:, : l.dim - d1], atol=1e-5)


class TestTying:
    def test_residual_stream_alignment(self):
        """B_emb ties the residual stream: with sp holding an identity-probe
        pattern, emb growth and o_w out-growth must use the same matrix."""
        small, large, sp, lp = setup()
        grown = ligo_apply(lp, sp, small, large)
        b_emb = np.asarray(lp["B_emb"])
        # emb_tok growth is exactly emb_tok @ B_emb^T
        want = np.asarray(sp["emb_tok"]) @ b_emb.T
        np.testing.assert_allclose(grown["emb_tok"], want, atol=1e-4)
        # final LN grows through the same matrix
        want_ln = np.asarray(sp["final_ln_g"]) @ b_emb.T
        np.testing.assert_allclose(grown["final_ln_g"], want_ln, atol=1e-4)

    def test_linearity_in_small_params(self):
        """vec(Theta_new) = M vec(Theta): doubling Theta doubles the output."""
        small, large, sp, lp = setup()
        g1 = ligo_apply(lp, sp, small, large)
        sp2 = {k: 2.0 * v for k, v in sp.items()}
        g2 = ligo_apply(lp, sp2, small, large)
        for k in g1:
            np.testing.assert_allclose(g2[k], 2.0 * g1[k], atol=1e-3, rtol=1e-4)

    def test_grown_model_forward_finite(self):
        small, large, sp, lp = setup()
        grown = ligo_apply(lp, sp, small, large)
        toks = jnp.array(np.random.RandomState(0).randint(4, 512, (2, large.seq)), jnp.int32)
        labels = jnp.where(toks % 5 == 0, toks, -1)
        loss = T.lm_loss(grown, {"tokens": toks, "labels": labels}, large)
        assert np.isfinite(float(loss))

    def test_m_is_differentiable(self):
        small, large, sp, lp = setup()
        toks = jnp.array(np.random.RandomState(0).randint(4, 512, (2, large.seq)), jnp.int32)
        labels = jnp.where(toks % 5 == 0, toks, -1)

        def loss_fn(lp):
            grown = ligo_apply(lp, sp, small, large)
            return T.lm_loss(grown, {"tokens": toks, "labels": labels}, large)

        grads = jax.grad(loss_fn)(lp)
        assert set(grads) == set(lp)
        total = sum(float(jnp.abs(g).sum()) for g in grads.values())
        assert np.isfinite(total) and total > 0.0
