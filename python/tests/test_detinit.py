"""Cross-language determinism: detinit must match rust/src/tensor/init.rs
bit for bit. The reference vectors here are asserted on BOTH sides."""

import numpy as np

from compile.detinit import det_fill, fnv1a, tensor_scale


class TestFnv:
    def test_reference_vectors(self):
        # mirrored in rust util::rng::tests::fnv_matches_python_reference
        assert fnv1a("") == 0xCBF29CE484222325
        assert fnv1a("a") == 0xAF63DC4C8601EC8C

    def test_distinct_names(self):
        assert fnv1a("L00_q_w") != fnv1a("L00_k_w")


class TestScaleRules:
    def test_suffix_rules(self):
        assert tensor_scale("L00_ln1_g", (48,)) == -1.0
        assert tensor_scale("L03_ls1", (48,)) == -2.0
        assert tensor_scale("L00_q_b", (48,)) == 0.0
        assert tensor_scale("mlm_bias", (512,)) == 0.0
        assert tensor_scale("emb_tok", (512, 48)) == 0.02
        s = tensor_scale("L00_q_w", (48, 48))
        assert abs(s - np.sqrt(6.0 / 96.0)) < 1e-7

    def test_glorot_depends_on_fans(self):
        assert tensor_scale("L00_fc1_w", (192, 48)) != tensor_scale("L00_q_w", (48, 48))


class TestDetFill:
    def test_deterministic(self):
        a = det_fill("L00_q_w", (8, 8))
        b = det_fill("L00_q_w", (8, 8))
        np.testing.assert_array_equal(a, b)

    def test_name_and_seed_sensitivity(self):
        a = det_fill("L00_q_w", (8, 8), 0)
        assert not np.array_equal(a, det_fill("L00_k_w", (8, 8), 0))
        assert not np.array_equal(a, det_fill("L00_q_w", (8, 8), 1))

    def test_constants(self):
        np.testing.assert_array_equal(det_fill("x_g", (4,)), np.ones(4, np.float32))
        np.testing.assert_array_equal(det_fill("x_b", (4,)), np.zeros(4, np.float32))
        np.testing.assert_allclose(det_fill("L01_ls1", (4,)), 0.1)

    def test_bounded_and_centered(self):
        t = det_fill("emb_tok", (64, 32))
        assert np.abs(t).max() <= 0.02 + 1e-7
        assert abs(t.mean()) < 0.002

    def test_known_first_values_stable(self):
        """Pin the exact first values — the contract with the Rust side."""
        t = det_fill("emb_tok", (4, 4)).reshape(-1)
        # recompute by hand with the documented scheme
        seed = np.uint32(fnv1a("emb_tok") & 0xFFFFFFFF)
        z = np.uint32(seed)  # i = 0 term: seed + 0
        for _ in range(2):
            z ^= z >> np.uint32(16)
            z = np.uint32((int(z) * 0x45D9F3B) & 0xFFFFFFFF)
        z ^= z >> np.uint32(16)
        want0 = ((int(z) / 4294967296.0) - 0.5) * 2.0 * 0.02
        assert abs(t[0] - want0) < 1e-9
