"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracle.

Hypothesis sweeps shapes/dtypes; every property asserts allclose against
ref.py. This is the CORE correctness signal for the compute layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ligo_expand import ligo_expand, ligo_expand_batched, _pick_block
from compile.kernels.attention import attention
from compile.kernels.ref import ligo_expand_ref, attention_ref, layernorm_ref

DIMS = st.sampled_from([1, 2, 3, 4, 8, 12, 16, 24, 48, 64, 96, 130])
SMALL_DIMS = st.sampled_from([1, 2, 4, 8, 16, 32])


def _rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


class TestLigoExpand:
    @settings(max_examples=25, deadline=None)
    @given(m=DIMS, k=SMALL_DIMS, n=SMALL_DIMS, p=DIMS)
    def test_matches_oracle_shapes(self, m, k, n, p):
        b, w, a = _rand(1, m, k), _rand(2, k, n), _rand(3, p, n)
        got = ligo_expand(b, w, a)
        want = ligo_expand_ref(b, w, a)
        assert got.shape == (m, p)
        np.testing.assert_allclose(got, want, atol=1e-3, rtol=1e-4)

    def test_identity_expansion_is_noop(self):
        w = _rand(0, 48, 48)
        eye = jnp.eye(48)
        np.testing.assert_allclose(ligo_expand(eye, w, eye), w, atol=1e-5)

    def test_paper_shapes_bert_small_to_base(self):
        # D1=512 -> D2=768 at paper scale (the real growth shapes)
        b, w, a = _rand(1, 768, 512), _rand(2, 512, 512), _rand(3, 768, 512)
        np.testing.assert_allclose(
            ligo_expand(b, w, a), ligo_expand_ref(b, w, a), atol=5e-2, rtol=1e-4
        )

    def test_rectangular_ffn_shapes(self):
        # fc1: (F2, F1) x (F1, D1) x (D2, D1)^T
        b, w, a = _rand(1, 288, 192), _rand(2, 192, 48), _rand(3, 72, 48)
        np.testing.assert_allclose(
            ligo_expand(b, w, a), ligo_expand_ref(b, w, a), atol=1e-3, rtol=1e-4
        )

    @settings(max_examples=10, deadline=None)
    @given(m=st.sampled_from([8, 48, 96]), layers=st.integers(1, 4))
    def test_batched_matches_loop(self, m, layers):
        b, a = _rand(1, m, 8), _rand(3, m, 8)
        ws = _rand(2, layers, 8, 8)
        got = ligo_expand_batched(b, ws, a)
        want = jnp.stack([ligo_expand_ref(b, ws[i], a) for i in range(layers)])
        np.testing.assert_allclose(got, want, atol=1e-3, rtol=1e-4)

    def test_gradients_match_oracle(self):
        b, w, a = _rand(1, 24, 8), _rand(2, 8, 8), _rand(3, 24, 8)

        def loss_k(b, w, a):
            return (ligo_expand(b, w, a) ** 2).sum()

        def loss_r(b, w, a):
            return (ligo_expand_ref(b, w, a) ** 2).sum()

        gk = jax.grad(loss_k, argnums=(0, 1, 2))(b, w, a)
        gr = jax.grad(loss_r, argnums=(0, 1, 2))(b, w, a)
        for x, y in zip(gk, gr):
            np.testing.assert_allclose(x, y, atol=1e-2, rtol=1e-3)

    def test_grad_through_vmap(self):
        b, a = _rand(1, 24, 8), _rand(3, 24, 8)
        ws = _rand(2, 3, 8, 8)

        def lk(b):
            return (ligo_expand_batched(b, ws, a) ** 3).sum()

        def lr(b):
            return sum(((ligo_expand_ref(b, ws[i], a)) ** 3).sum() for i in range(3))

        np.testing.assert_allclose(jax.grad(lk)(b), jax.grad(lr)(b), atol=1e-2, rtol=1e-3)

    def test_pick_block_divides(self):
        for dim in (1, 2, 3, 7, 48, 96, 130, 768):
            for t in (8, 64, 128):
                b = _pick_block(dim, t)
                assert dim % b == 0 and 1 <= b <= max(dim, 1)

    def test_linearity_in_w(self):
        """The growth operator is linear in the small model's weights (Eq. 4)."""
        b, a = _rand(1, 12, 8), _rand(3, 12, 8)
        w1, w2 = _rand(2, 8, 8), _rand(4, 8, 8)
        lhs = ligo_expand(b, w1 + 2.0 * w2, a)
        rhs = ligo_expand(b, w1, a) + 2.0 * ligo_expand(b, w2, a)
        np.testing.assert_allclose(lhs, rhs, atol=1e-3, rtol=1e-4)


class TestAttention:
    @settings(max_examples=20, deadline=None)
    @given(
        bh=st.sampled_from([1, 2, 6]),
        s=st.sampled_from([4, 16, 32, 64, 96]),
        dh=st.sampled_from([4, 8, 12, 16]),
        causal=st.booleans(),
    )
    def test_matches_oracle(self, bh, s, dh, causal):
        q, k, v = _rand(1, bh, s, dh), _rand(2, bh, s, dh), _rand(3, bh, s, dh)
        got = attention(q, k, v, causal)
        want = attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(got, want, atol=2e-4, rtol=1e-4)

    def test_causal_first_token_attends_self_only(self):
        q, k, v = _rand(1, 1, 8, 4), _rand(2, 1, 8, 4), _rand(3, 1, 8, 4)
        out = attention(q, k, v, True)
        np.testing.assert_allclose(out[0, 0], v[0, 0], atol=1e-5)

    def test_permutation_equivariance_noncausal(self):
        """Bidirectional attention output is invariant to permuting K/V pairs."""
        q, k, v = _rand(1, 1, 16, 4), _rand(2, 1, 16, 4), _rand(3, 1, 16, 4)
        perm = jnp.array(np.random.RandomState(0).permutation(16))
        out1 = attention(q, k, v, False)
        out2 = attention(q, k[:, perm], v[:, perm], False)
        np.testing.assert_allclose(out1, out2, atol=1e-4)

    def test_uniform_values_passthrough(self):
        """If V is constant, output equals that constant regardless of scores."""
        q, k = _rand(1, 2, 16, 4), _rand(2, 2, 16, 4)
        v = jnp.ones((2, 16, 4))
        np.testing.assert_allclose(attention(q, k, v, False), v, atol=1e-5)

    def test_grads_match_oracle(self):
        q, k, v = _rand(1, 2, 16, 4), _rand(2, 2, 16, 4), _rand(3, 2, 16, 4)
        for causal in (False, True):
            gk = jax.grad(lambda q, k, v: (attention(q, k, v, causal) ** 2).sum(),
                          argnums=(0, 1, 2))(q, k, v)
            gr = jax.grad(lambda q, k, v: (attention_ref(q, k, v, causal=causal) ** 2).sum(),
                          argnums=(0, 1, 2))(q, k, v)
            for x, y in zip(gk, gr):
                np.testing.assert_allclose(x, y, atol=1e-3, rtol=1e-3)

    def test_odd_seq_falls_back_to_smaller_blocks(self):
        # S=24 not divisible by 64: block-size fallback path
        q, k, v = _rand(1, 1, 24, 8), _rand(2, 1, 24, 8), _rand(3, 1, 24, 8)
        np.testing.assert_allclose(
            attention(q, k, v, True), attention_ref(q, k, v, causal=True), atol=2e-4
        )


class TestLayerNormRef:
    def test_normalizes(self):
        x = _rand(1, 4, 32)
        y = layernorm_ref(x, jnp.ones(32), jnp.zeros(32))
        np.testing.assert_allclose(y.mean(-1), 0.0, atol=1e-5)
        np.testing.assert_allclose(y.std(-1), 1.0, atol=1e-2)
