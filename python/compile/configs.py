"""Model configuration registry — single source of truth for both layers.

`aot.py` exports this registry to `artifacts/configs.json`; the Rust
coordinator reads that file for its presets, so python and rust can never
disagree about shapes.

Presets mirror the paper's Table 4 families at a scale that trains in
minutes on one CPU core, preserving the growth *ratios* that drive every
figure (depth 6->12 ~= 2x, width 512->768 = 1.5x), plus a ~100M-parameter
`e2e` pair for the end-to-end driver.
"""

from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str          # bert | gpt | vit | cait
    layers: int
    dim: int
    heads: int
    vocab: int = 0       # text families
    seq: int = 0         # text: tokens; vision: derived
    batch: int = 16      # the batch baked into this config's artifacts
    img: int = 0         # vision: image side
    patch: int = 0       # vision: patch side
    channels: int = 3
    n_classes: int = 0   # vision / probe heads
    cls_layers: int = 0  # cait: class-attention layers
    ffn_mult: int = 4

    @property
    def ffn(self) -> int:
        return self.ffn_mult * self.dim

    @property
    def tokens(self) -> int:
        """Sequence length seen by the transformer body."""
        if self.family in ("vit", "cait"):
            n = (self.img // self.patch) ** 2
            return n + (1 if self.family == "vit" else 0)  # cait: cls joins later
        return self.seq

    @property
    def head_dim(self) -> int:
        assert self.dim % self.heads == 0
        return self.dim // self.heads


# ----------------------------------------------------------------------------
# Preset registry. Scale factor vs the paper: dims /~10, layers /2, vocab
# synthetic. Ratios (the quantity the experiments measure) are preserved.
# ----------------------------------------------------------------------------
_P = [
    # BERT family (paper: Small 6L/512, Base 12L/768, Large 24L/1024)
    ModelConfig("bert_small", "bert", layers=3, dim=48, heads=4, vocab=512, seq=32, batch=16),
    ModelConfig("bert_base", "bert", layers=6, dim=72, heads=6, vocab=512, seq=32, batch=16),
    ModelConfig("bert_large", "bert", layers=12, dim=96, heads=8, vocab=512, seq=32, batch=16),
    # Ablation sources: depth-only (same width as base) and width-only (same depth)
    ModelConfig("bert_d3w72", "bert", layers=3, dim=72, heads=6, vocab=512, seq=32, batch=16),
    ModelConfig("bert_d6w48", "bert", layers=6, dim=48, heads=4, vocab=512, seq=32, batch=16),
    # GPT2 family (paper: Base 12L/768, Medium 24L/1024)
    ModelConfig("gpt_base", "gpt", layers=6, dim=64, heads=4, vocab=512, seq=64, batch=8),
    ModelConfig("gpt_medium", "gpt", layers=12, dim=96, heads=6, vocab=512, seq=64, batch=8),
    # DeiT family (paper: S 12L/384, B 12L/768 — width-dominant growth)
    ModelConfig("vit_s", "vit", layers=6, dim=48, heads=4, img=32, patch=8, n_classes=10, batch=16),
    ModelConfig("vit_b", "vit", layers=6, dim=96, heads=8, img=32, patch=8, n_classes=10, batch=16),
    # CaiT family (paper: XS 24L/288, S 24L/384) — has class-attention stage
    ModelConfig("cait_xs", "cait", layers=6, dim=48, heads=4, img=32, patch=8, n_classes=10,
                cls_layers=2, batch=16),
    ModelConfig("cait_s", "cait", layers=6, dim=64, heads=4, img=32, patch=8, n_classes=10,
                cls_layers=2, batch=16),
    # End-to-end pair: ~25M -> ~91M params (the required ~100M driver)
    ModelConfig("e2e_small", "bert", layers=6, dim=512, heads=8, vocab=8192, seq=64, batch=4),
    ModelConfig("e2e_base", "bert", layers=12, dim=768, heads=12, vocab=8192, seq=64, batch=4),
    # Transfer probes (bodies share bert/vit names; heads are task-specific)
    ModelConfig("probe_bert_base", "bert", layers=6, dim=72, heads=6, vocab=512, seq=32,
                n_classes=4, batch=16),
    ModelConfig("probe_bert_small", "bert", layers=3, dim=48, heads=4, vocab=512, seq=32,
                n_classes=4, batch=16),
    ModelConfig("probe_vit_b", "vit", layers=6, dim=96, heads=8, img=32, patch=8,
                n_classes=20, batch=16),
]

REGISTRY = {c.name: c for c in _P}

# LiGO growth pairs (small -> large). Tuple: (source, target)
PAIRS = [
    ("bert_small", "bert_base"),
    ("bert_small", "bert_large"),
    ("bert_base", "bert_large"),
    ("bert_d3w72", "bert_base"),   # depth-only: 3L->6L, width 72 fixed
    ("bert_d6w48", "bert_base"),   # width-only: 48->72, depth 6 fixed
    ("gpt_base", "gpt_medium"),
    ("vit_s", "vit_b"),
    ("cait_xs", "cait_s"),
    ("e2e_small", "e2e_base"),
]

# Knowledge-distillation (KI baseline) pairs
KD_PAIRS = [("bert_small", "bert_base"), ("vit_s", "vit_b")]


def param_count(cfg: ModelConfig) -> int:
    """Analytic parameter count (mirrors rust/src/config/flops.rs)."""
    d, f, l = cfg.dim, cfg.ffn, cfg.layers
    per_layer = 4 * d * d + 4 * d + d * f + f + f * d + d + 4 * d
    n = l * per_layer
    if cfg.family in ("bert", "gpt"):
        n += cfg.vocab * d + cfg.seq * d + cfg.vocab  # tok+pos+mlm_bias (tied head)
        n += 2 * d  # final/emb ln
    if cfg.family in ("vit", "cait"):
        pdim = cfg.patch * cfg.patch * cfg.channels
        n += d * pdim + d + d + cfg.tokens * d  # patch w+b, cls, pos
        n += cfg.n_classes * d + cfg.n_classes + 2 * d
        if cfg.family == "cait":
            n += cfg.cls_layers * per_layer + l * 2 * d  # cls layers + layerscale
    if cfg.n_classes and cfg.family == "bert":
        n += cfg.n_classes * d + cfg.n_classes
    return n


def to_json() -> dict:
    return {
        "models": {k: asdict(v) for k, v in REGISTRY.items()},
        "pairs": PAIRS,
        "kd_pairs": KD_PAIRS,
        "param_counts": {k: param_count(v) for k, v in REGISTRY.items()},
    }
