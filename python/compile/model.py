"""L2: graph builders — one entry per AOT artifact.

Each builder returns ``(fn, example_args)`` where ``example_args`` is a tuple
of flat {name: ShapeDtypeStruct} dicts. `aot.py` lowers ``jax.jit(fn)`` on the
examples, converts to HLO text, and emits a manifest describing the flattened
input/output order (dicts flatten in sorted-key order) so the Rust runtime can
bind its named tensor store positionally.

Artifact taxonomy (names are the Rust-facing API):
  fwd_{model}            (params, batch) -> (loss[, metric])        eval
  grad_{model}           (params, batch) -> (loss[, metric], grads) training
  grad_gated_{model}     + layer gates & token-keep mask            Fig. 5
  kd_grad_{s}__{t}       (params_t, params_s, batch) -> (loss, grads_t)  KI baseline
  ligo_grad_{s}__{t}     (ligo, params_s, batch) -> (loss, dligo)   the 100 M-steps
  ligo_apply_{s}__{t}    (ligo, params_s) -> params_t               growth
  span_/adapter_ variants for the transfer-learning tables
"""

import jax
import jax.numpy as jnp

from . import transformer as T
from .configs import REGISTRY, PAIRS, KD_PAIRS, ModelConfig
from .ligo import ligo_init, ligo_apply


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def param_shapes(cfg: ModelConfig, with_adapters=False, with_span=False) -> dict:
    """{name: shape} for a config — derived via abstract eval (no FLOPs)."""
    p = jax.eval_shape(
        lambda k: T.init_params(k, cfg, with_adapters=with_adapters, with_span=with_span),
        jax.random.PRNGKey(0),
    )
    return {k: v.shape for k, v in p.items()}


def param_specs(cfg: ModelConfig, **kw) -> dict:
    return {k: _spec(s) for k, s in param_shapes(cfg, **kw).items()}


def batch_specs(cfg: ModelConfig) -> dict:
    if cfg.family in ("vit", "cait"):
        return {
            "images": _spec((cfg.batch, cfg.img, cfg.img, cfg.channels)),
            "labels": _spec((cfg.batch,), jnp.int32),
        }
    if cfg.n_classes:  # probe
        return {
            "tokens": _spec((cfg.batch, cfg.seq), jnp.int32),
            "labels": _spec((cfg.batch,), jnp.int32),
        }
    return {
        "tokens": _spec((cfg.batch, cfg.seq), jnp.int32),
        "labels": _spec((cfg.batch, cfg.seq), jnp.int32),
    }


def ligo_specs(small: ModelConfig, large: ModelConfig) -> dict:
    lp = jax.eval_shape(lambda k: ligo_init(k, small, large), jax.random.PRNGKey(0))
    return {k: _spec(v.shape) for k, v in lp.items()}


# ----------------------------------------------------------------------------
# Loss dispatch
# ----------------------------------------------------------------------------

def _loss_fn(cfg: ModelConfig):
    """Returns fn(params, batch) -> (loss, aux) with aux a dict of metrics."""
    if cfg.family in ("vit", "cait"):
        def f(p, b):
            loss, acc = T.vision_loss(p, b, cfg)
            return loss, {"acc": acc}
        return f
    if cfg.n_classes:
        def f(p, b):
            loss, acc = T.probe_loss(p, b, cfg)
            return loss, {"acc": acc}
        return f
    def f(p, b):
        return T.lm_loss(p, b, cfg), {}
    return f


# ----------------------------------------------------------------------------
# Builders
# ----------------------------------------------------------------------------

def build_fwd(cfg):
    lf = _loss_fn(cfg)
    def fn(params, batch):
        loss, aux = lf(params, batch)
        return (loss, aux["acc"]) if "acc" in aux else (loss,)
    return fn, (param_specs(cfg), batch_specs(cfg))


def build_grad(cfg):
    lf = _loss_fn(cfg)
    def fn(params, batch):
        (loss, aux), grads = jax.value_and_grad(lf, has_aux=True)(params, batch)
        if "acc" in aux:
            return loss, aux["acc"], grads
        return loss, grads
    return fn, (param_specs(cfg), batch_specs(cfg))


def build_grad_gated(cfg):
    def fn(params, batch):
        def lf(p):
            return T.lm_loss(p, batch, cfg, gates=batch["gates"],
                             token_keep=batch["token_keep"])
        loss, grads = jax.value_and_grad(lf)(params)
        return loss, grads
    bs = batch_specs(cfg)
    bs["gates"] = _spec((cfg.layers,))
    bs["token_keep"] = _spec((cfg.batch, cfg.seq))
    return fn, (param_specs(cfg), bs)


def build_kd_grad(small, large):
    def fn(params_l, params_s, batch):
        def lf(pl):
            return T.kd_loss(params_s, pl, batch, small, large)
        loss, grads = jax.value_and_grad(lf)(params_l)
        return loss, grads
    return fn, (param_specs(large), param_specs(small), batch_specs(large))


def build_ligo_grad(small, large):
    lf_large = _loss_fn(large)
    def fn(lparams, params_s, batch):
        def lf(lp):
            grown = ligo_apply(lp, params_s, small, large)
            loss, _aux = lf_large(grown, batch)
            return loss
        loss, dl = jax.value_and_grad(lf)(lparams)
        return loss, dl
    return fn, (ligo_specs(small, large), param_specs(small), batch_specs(large))


def build_ligo_apply(small, large):
    def fn(lparams, params_s):
        return ligo_apply(lparams, params_s, small, large)
    return fn, (ligo_specs(small, large), param_specs(small))


def build_span_fwd(cfg):
    def fn(params, batch):
        loss, em = T.span_loss(params, batch, cfg)
        return loss, em
    bs = {
        "tokens": _spec((cfg.batch, cfg.seq), jnp.int32),
        "starts": _spec((cfg.batch,), jnp.int32),
        "ends": _spec((cfg.batch,), jnp.int32),
    }
    return fn, (param_specs(cfg, with_span=True), bs)


def build_span_grad(cfg):
    def fn(params, batch):
        def lf(p):
            loss, em = T.span_loss(p, batch, cfg)
            return loss, em
        (loss, em), grads = jax.value_and_grad(lf, has_aux=True)(params)
        return loss, em, grads
    _, (ps, bs) = build_span_fwd(cfg)
    return fn, (ps, bs)


def _is_adapter_key(k):
    return ("_ad1_" in k) or ("_ad2_" in k) or k in ("head_w", "head_b")


def build_adapter_grad(cfg):
    """Adapter-tuning (Table 6): grads only for adapter + head parameters."""
    def fn(trainable, frozen, batch):
        def lf(tr):
            p = dict(frozen)
            p.update(tr)
            return T.probe_loss(p, batch, cfg)
        (loss, acc), grads = jax.value_and_grad(lf, has_aux=True)(trainable)
        return loss, acc, grads
    allp = param_specs(cfg, with_adapters=True)
    trainable = {k: v for k, v in allp.items() if _is_adapter_key(k)}
    frozen = {k: v for k, v in allp.items() if not _is_adapter_key(k)}
    return fn, (trainable, frozen, batch_specs(cfg))


def build_adapter_fwd(cfg):
    def fn(trainable, frozen, batch):
        p = dict(frozen)
        p.update(trainable)
        return T.probe_loss(p, batch, cfg)
    _, (tr, fr, bs) = build_adapter_grad(cfg)
    return fn, (tr, fr, bs)


# ----------------------------------------------------------------------------
# Full artifact registry
# ----------------------------------------------------------------------------

def artifact_registry() -> dict:
    """name -> (builder, cfg...) for every artifact in the repo."""
    arts = {}
    for name, cfg in REGISTRY.items():
        arts[f"fwd_{name}"] = (build_fwd, cfg)
        arts[f"grad_{name}"] = (build_grad, cfg)
    for s, t in PAIRS:
        cs, ct = REGISTRY[s], REGISTRY[t]
        arts[f"ligo_grad_{s}__{t}"] = (build_ligo_grad, cs, ct)
        arts[f"ligo_apply_{s}__{t}"] = (build_ligo_apply, cs, ct)
    for s, t in KD_PAIRS:
        arts[f"kd_grad_{s}__{t}"] = (build_kd_grad, REGISTRY[s], REGISTRY[t])
    for name in ("bert_small", "bert_base"):
        arts[f"grad_gated_{name}"] = (build_grad_gated, REGISTRY[name])
    arts["span_fwd_bert_base"] = (build_span_fwd, REGISTRY["probe_bert_base"])
    arts["span_grad_bert_base"] = (build_span_grad, REGISTRY["probe_bert_base"])
    arts["adapter_fwd_bert_base"] = (build_adapter_fwd, REGISTRY["probe_bert_base"])
    arts["adapter_grad_bert_base"] = (build_adapter_grad, REGISTRY["probe_bert_base"])
    return arts


def build(name):
    """Instantiate (fn, example_specs) for an artifact name."""
    entry = artifact_registry()[name]
    builder, *args = entry
    return builder(*args)
