"""Performance analysis for L1/L2 (structural — interpret-mode wallclock is
not a TPU proxy, so we analyze what the lowering/BlockSpecs imply).

  python -m compile.perf            # full report
  python -m compile.perf --l1       # kernel VMEM/MXU estimates only

L1: for each Pallas kernel, compute the VMEM working set per grid step from
the BlockSpecs and estimate MXU utilization (fraction of lane/sublane-aligned
work) at both repo scale and paper scale (512->768).

L2: jax cost analysis of the lowered training graphs: FLOPs, bytes accessed,
arithmetic intensity; verifies the analytic rust FLOPs model
(rust/src/coordinator/flops.rs) against XLA's own counts.
"""

import argparse

import jax
import numpy as np

from . import model as M
from .configs import REGISTRY

VMEM_BYTES = 16 * 1024 * 1024  # per-core VMEM on modern TPUs
MXU = 128  # systolic array dim


def _align_frac(d, unit):
    """Fraction of useful work when d is padded up to `unit`."""
    pad = ((d + unit - 1) // unit) * unit
    return d / pad


def l1_report():
    print("== L1 Pallas kernels: VMEM footprint + MXU utilization estimate ==")
    print("(interpret=True on CPU: structure, not wallclock, is what transfers)")
    # ligo_expand: blocks (bm,bk) of B, (bk,n) of W, (bp,n) of A, (bm,bp) out
    for label, (m, k, n, p) in {
        "ligo_expand repo-scale fc1 (288x48 <- 192x48)": (288, 192, 48, 72),
        "ligo_expand paper-scale qkv (768<-512)": (768, 512, 512, 768),
        "ligo_expand paper-scale fc1 (3072<-2048)": (3072, 2048, 512, 768),
    }.items():
        bm, bp, bk = min(m, 128), min(p, 128), min(k, 128)
        vmem = 4 * (bm * bk + bk * n + bp * n + bm * bp)
        grid = (m // bm) * (p // bp) * (k // bk)
        util = (
            _align_frac(bm, 8) * _align_frac(bk, MXU)
            + _align_frac(bk, 8) * _align_frac(bp, MXU)
        ) / 2
        flops = 2 * k * n * p + 2 * m * k * p
        print(f"  {label}")
        print(
            f"    tiles ({bm},{bp},{bk}) grid={grid:4d}  VMEM/step {vmem/1024:8.1f} KiB"
            f" ({vmem/VMEM_BYTES*100:4.1f}% of 16MiB)  est. MXU util {util*100:5.1f}%"
            f"  {flops/1e6:.2f} MFLOP"
        )
    # attention: (1,bq,dh) q tile, (1,S,dh) k/v, online softmax
    for label, (bh, s, dh, bq, bk) in {
        "attention repo-scale (bert_base)": (96, 32, 12, 32, 32),
        "attention paper-scale (bert-base 512 tok)": (192, 512, 64, 64, 64),
    }.items():
        vmem = 4 * (bq * dh + 2 * s * dh + bq * dh + bq * bk)
        util = _align_frac(dh, MXU) * _align_frac(bk, 8)
        print(f"  {label}")
        print(
            f"    q-tile {bq}, k-tile {bk}, dh {dh}: VMEM/step {vmem/1024:8.1f} KiB"
            f"  est. MXU util {util*100:5.1f}% (dh<{MXU} pads the systolic array;"
            f" heads should be fused at paper scale)"
        )


def l2_report():
    print("\n== L2 lowered-graph cost analysis (XLA's own counts) ==")
    for name in ("grad_bert_small", "grad_bert_base", "ligo_grad_bert_small__bert_base"):
        fn, specs = M.build(name)
        compiled = jax.jit(fn, keep_unused=True).lower(*specs).compile()
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        flops = ca.get("flops", float("nan"))
        bytes_ = ca.get("bytes accessed", float("nan"))
        print(
            f"  {name:<40} flops {flops:12.3e}  bytes {bytes_:12.3e}"
            f"  intensity {flops/max(bytes_,1):6.2f} flop/B"
        )
    # verify the rust analytic model against XLA for one graph
    cfg = REGISTRY["bert_base"]
    d, f, s, layers = cfg.dim, 4 * cfg.dim, cfg.seq, cfg.layers
    per_tok = layers * (8 * d * d + 4 * s * d + 4 * d * f) + 2 * d * cfg.vocab
    analytic = 3.0 * per_tok * cfg.batch * cfg.seq
    fn, specs = M.build("grad_bert_base")
    compiled = jax.jit(fn, keep_unused=True).lower(*specs).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    xla_flops = ca.get("flops", float("nan"))
    print(
        f"  analytic train-step model {analytic:.3e} vs XLA {xla_flops:.3e}"
        f" (ratio {xla_flops/analytic:.2f} — XLA counts exact ops incl. LN/softmax)"
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--l1", action="store_true")
    ap.add_argument("--l2", action="store_true")
    args = ap.parse_args()
    if args.l1 or not args.l2:
        l1_report()
    if args.l2 or not args.l1:
        l2_report()


if __name__ == "__main__":
    main()
