"""L2: the transformer family (BERT / GPT2 / DeiT / CaiT analogs) in JAX.

Parameters live in a FLAT dict of name -> array with zero-padded layer
prefixes ("L03_q_w"), so the sorted-key order (which is what jax.jit's pytree
flattening and therefore the AOT manifests use) is stable and identical to
the Rust tensor store's ordering.

Weight convention: all projection matrices are stored (out_dim, in_dim),
matching the paper's ``y = W x`` formulas (forward uses ``x @ w.T``), which
keeps the LiGO expansion literally ``B @ W @ A^T``.

Attention runs through the L1 Pallas kernel (`kernels.attention`).
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels.attention import attention

ADAPTER_DIM = 8


# ----------------------------------------------------------------------------
# Initialization
# ----------------------------------------------------------------------------

def _dense_init(key, out_dim, in_dim, scale=None):
    scale = scale if scale is not None else (2.0 / (in_dim + out_dim)) ** 0.5
    return jax.random.normal(key, (out_dim, in_dim), jnp.float32) * scale


def _layer_params(key, d, f, prefix):
    ks = jax.random.split(key, 8)
    p = {}
    for i, m in enumerate(("q", "k", "v", "o")):
        p[f"{prefix}{m}_w"] = _dense_init(ks[i], d, d)
        p[f"{prefix}{m}_b"] = jnp.zeros((d,), jnp.float32)
    p[f"{prefix}fc1_w"] = _dense_init(ks[4], f, d)
    p[f"{prefix}fc1_b"] = jnp.zeros((f,), jnp.float32)
    p[f"{prefix}fc2_w"] = _dense_init(ks[5], d, f)
    p[f"{prefix}fc2_b"] = jnp.zeros((d,), jnp.float32)
    for ln in ("ln1", "ln2"):
        p[f"{prefix}{ln}_g"] = jnp.ones((d,), jnp.float32)
        p[f"{prefix}{ln}_b"] = jnp.zeros((d,), jnp.float32)
    return p


def init_params(key, cfg: ModelConfig, with_adapters: bool = False,
                with_span: bool = False) -> dict:
    """Random init of the flat parameter dict for any family."""
    d, f = cfg.dim, cfg.ffn
    keys = jax.random.split(key, cfg.layers + cfg.cls_layers + 8)
    p = {}
    if cfg.family in ("bert", "gpt"):
        p["emb_tok"] = _dense_init(keys[-1], cfg.vocab, d, scale=0.02)
        p["emb_pos"] = _dense_init(keys[-2], cfg.seq, d, scale=0.02)
        p["mlm_bias"] = jnp.zeros((cfg.vocab,), jnp.float32)
        p["final_ln_g"] = jnp.ones((d,), jnp.float32)
        p["final_ln_b"] = jnp.zeros((d,), jnp.float32)
    else:
        pdim = cfg.patch * cfg.patch * cfg.channels
        p["emb_patch_w"] = _dense_init(keys[-1], d, pdim)
        p["emb_patch_b"] = jnp.zeros((d,), jnp.float32)
        p["emb_cls"] = _dense_init(keys[-2], 1, d, scale=0.02).reshape(d)
        n_pos = cfg.tokens if cfg.family == "vit" else cfg.tokens
        p["emb_pos"] = _dense_init(keys[-3], n_pos, d, scale=0.02)
        p["final_ln_g"] = jnp.ones((d,), jnp.float32)
        p["final_ln_b"] = jnp.zeros((d,), jnp.float32)
        p["head_w"] = _dense_init(keys[-4], cfg.n_classes, d, scale=0.02)
        p["head_b"] = jnp.zeros((cfg.n_classes,), jnp.float32)
    for l in range(cfg.layers):
        p.update(_layer_params(keys[l], d, f, f"L{l:02d}_"))
        if cfg.family == "cait":
            p[f"L{l:02d}_ls1"] = jnp.full((d,), 1e-1, jnp.float32)
            p[f"L{l:02d}_ls2"] = jnp.full((d,), 1e-1, jnp.float32)
    for l in range(cfg.cls_layers):
        p.update(_layer_params(keys[cfg.layers + l], d, f, f"C{l:02d}_"))
    if cfg.n_classes and cfg.family == "bert":
        p["head_w"] = _dense_init(keys[-5], cfg.n_classes, d, scale=0.02)
        p["head_b"] = jnp.zeros((cfg.n_classes,), jnp.float32)
    if with_span:
        p["span_w"] = _dense_init(keys[-6], 2, d, scale=0.02)
        p["span_b"] = jnp.zeros((2,), jnp.float32)
    if with_adapters:
        for l in range(cfg.layers):
            kk = jax.random.split(keys[l], 2)
            p[f"L{l:02d}_ad1_w"] = _dense_init(kk[0], ADAPTER_DIM, d, scale=0.01)
            p[f"L{l:02d}_ad1_b"] = jnp.zeros((ADAPTER_DIM,), jnp.float32)
            p[f"L{l:02d}_ad2_w"] = _dense_init(kk[1], d, ADAPTER_DIM, scale=0.01)
            p[f"L{l:02d}_ad2_b"] = jnp.zeros((d,), jnp.float32)
    return p


# ----------------------------------------------------------------------------
# Building blocks
# ----------------------------------------------------------------------------

def layer_norm(x, g, b, eps=1e-5):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _linear(x, p, name):
    return x @ p[f"{name}_w"].T + p[f"{name}_b"]


def _mha(x_q, x_kv, p, prefix, heads, causal):
    """Multi-head attention through the Pallas kernel."""
    bsz, s_q, d = x_q.shape
    s_k = x_kv.shape[1]
    dh = d // heads
    q = _linear(x_q, p, f"{prefix}q").reshape(bsz, s_q, heads, dh)
    k = _linear(x_kv, p, f"{prefix}k").reshape(bsz, s_k, heads, dh)
    v = _linear(x_kv, p, f"{prefix}v").reshape(bsz, s_k, heads, dh)
    q = q.transpose(0, 2, 1, 3).reshape(bsz * heads, s_q, dh)
    k = k.transpose(0, 2, 1, 3).reshape(bsz * heads, s_k, dh)
    v = v.transpose(0, 2, 1, 3).reshape(bsz * heads, s_k, dh)
    o = attention(q, k, v, causal)
    o = o.reshape(bsz, heads, s_q, dh).transpose(0, 2, 1, 3).reshape(bsz, s_q, d)
    return _linear(o, p, f"{prefix}o")


def _ffn(x, p, prefix):
    h = jax.nn.gelu(_linear(x, p, f"{prefix}fc1"))
    return _linear(h, p, f"{prefix}fc2")


def _adapter(x, p, prefix):
    if f"{prefix}ad1_w" not in p:
        return x
    h = jax.nn.gelu(_linear(x, p, f"{prefix}ad1"))
    return x + _linear(h, p, f"{prefix}ad2")


def _block_postln(x, p, prefix, heads, causal=False):
    """BERT-style post-LN block."""
    h = _mha(x, x, p, prefix, heads, causal)
    h = _adapter(h, p, prefix)
    x = layer_norm(x + h, p[f"{prefix}ln1_g"], p[f"{prefix}ln1_b"])
    h = _ffn(x, p, prefix)
    h = _adapter(h, p, prefix)
    x = layer_norm(x + h, p[f"{prefix}ln2_g"], p[f"{prefix}ln2_b"])
    return x


def _block_preln(x, p, prefix, heads, causal=False, layerscale=False,
                 gate=None, token_keep=None):
    """GPT/ViT-style pre-LN block, optionally LayerScale'd (CaiT) and gated
    (layer dropping / token dropping, Fig. 5)."""
    h = _mha(layer_norm(x, p[f"{prefix}ln1_g"], p[f"{prefix}ln1_b"]),
             layer_norm(x, p[f"{prefix}ln1_g"], p[f"{prefix}ln1_b"]), p, prefix, heads, causal)
    if layerscale:
        h = h * p[f"{prefix}ls1"]
    if gate is not None:
        h = h * gate
    if token_keep is not None:
        h = h * token_keep[..., None]
    x = x + h
    h = _ffn(layer_norm(x, p[f"{prefix}ln2_g"], p[f"{prefix}ln2_b"]), p, prefix)
    if layerscale:
        h = h * p[f"{prefix}ls2"]
    if gate is not None:
        h = h * gate
    if token_keep is not None:
        h = h * token_keep[..., None]
    return x + h


def _class_attn_block(cls_tok, patches, p, prefix, heads):
    """CaiT class-attention: the CLS token attends to the (frozen) patch
    sequence; only the CLS stream is updated."""
    xs = jnp.concatenate([cls_tok, patches], axis=1)
    h = _mha(layer_norm(cls_tok, p[f"{prefix}ln1_g"], p[f"{prefix}ln1_b"]),
             layer_norm(xs, p[f"{prefix}ln1_g"], p[f"{prefix}ln1_b"]),
             p, prefix, heads, causal=False)
    cls_tok = cls_tok + h
    h = _ffn(layer_norm(cls_tok, p[f"{prefix}ln2_g"], p[f"{prefix}ln2_b"]), p, prefix)
    return cls_tok + h


# ----------------------------------------------------------------------------
# Family encoders
# ----------------------------------------------------------------------------

def encode_text(p, tokens, cfg: ModelConfig, gates=None, token_keep=None):
    """BERT (post-LN, bidirectional) or GPT (pre-LN, causal) body -> (B,S,D).

    gates: optional (L,) layer gate vector (layer dropping). token_keep:
    optional (B,S) keep mask applied in the middle third of layers (token
    dropping). Gated runs use pre-LN blocks (post-LN is incompatible with
    stochastic depth; cf. Zhang & He 2020).
    """
    s = tokens.shape[1]
    x = p["emb_tok"][tokens] + p["emb_pos"][:s]
    causal = cfg.family == "gpt"
    gated = gates is not None or token_keep is not None
    lo, hi = cfg.layers // 3, 2 * cfg.layers // 3
    # NOTE: both families use pre-LN blocks. The original BERT is post-LN,
    # but post-LN depth-scaling instability (well documented; cf. Xiong et
    # al. 2020) dominates the growth comparisons at this substrate's short
    # step budgets, so the BERT analog is pre-LN (see DESIGN.md §4). The
    # post-LN block is kept (`_block_postln`) for adapter probes and tests.
    for l in range(cfg.layers):
        prefix = f"L{l:02d}_"
        if gated:
            g = gates[l] if gates is not None else None
            tk = token_keep if (token_keep is not None and lo <= l < hi) else None
            x = _block_preln(x, p, prefix, cfg.heads, causal, gate=g, token_keep=tk)
        else:
            x = _block_preln(x, p, prefix, cfg.heads, causal)
    return layer_norm(x, p["final_ln_g"], p["final_ln_b"])


def _patchify(images, patch):
    """(B, H, W, C) -> (B, T, patch*patch*C)."""
    b, h, w, c = images.shape
    nh, nw = h // patch, w // patch
    x = images.reshape(b, nh, patch, nw, patch, c)
    x = x.transpose(0, 1, 3, 2, 4, 5).reshape(b, nh * nw, patch * patch * c)
    return x


def encode_vision(p, images, cfg: ModelConfig):
    """ViT / CaiT body -> CLS representation (B, D)."""
    x = _patchify(images, cfg.patch) @ p["emb_patch_w"].T + p["emb_patch_b"]
    if cfg.family == "vit":
        cls_tok = jnp.broadcast_to(p["emb_cls"], (x.shape[0], 1, cfg.dim))
        x = jnp.concatenate([cls_tok, x], axis=1)
        x = x + p["emb_pos"][: x.shape[1]]
        for l in range(cfg.layers):
            x = _block_preln(x, p, f"L{l:02d}_", cfg.heads)
        x = layer_norm(x, p["final_ln_g"], p["final_ln_b"])
        return x[:, 0]
    # CaiT: patch self-attention stage (LayerScale), then class-attention
    x = x + p["emb_pos"][: x.shape[1]]
    for l in range(cfg.layers):
        x = _block_preln(x, p, f"L{l:02d}_", cfg.heads, layerscale=True)
    cls_tok = jnp.broadcast_to(p["emb_cls"], (x.shape[0], 1, cfg.dim))
    for l in range(cfg.cls_layers):
        cls_tok = _class_attn_block(cls_tok, x, p, f"C{l:02d}_", cfg.heads)
    cls_tok = layer_norm(cls_tok, p["final_ln_g"], p["final_ln_b"])
    return cls_tok[:, 0]


# ----------------------------------------------------------------------------
# Losses / task heads
# ----------------------------------------------------------------------------

def _masked_xent(logits, labels):
    """Cross entropy over positions with label >= 0; mean over those."""
    v = logits.shape[-1]
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = logz - ll
    mask = (labels >= 0).astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def lm_loss(p, batch, cfg: ModelConfig, gates=None, token_keep=None):
    """MLM (bert) / causal-LM (gpt) loss. batch: tokens (B,S) i32, labels (B,S) i32."""
    h = encode_text(p, batch["tokens"], cfg, gates=gates, token_keep=token_keep)
    logits = h @ p["emb_tok"].T + p["mlm_bias"]
    return _masked_xent(logits, batch["labels"])


def vision_loss(p, batch, cfg: ModelConfig):
    """Image classification loss + accuracy. batch: images (B,H,W,C) f32, labels (B,) i32."""
    h = encode_vision(p, batch["images"], cfg)
    logits = h @ p["head_w"].T + p["head_b"]
    loss = _masked_xent(logits, batch["labels"])
    acc = (logits.argmax(-1) == batch["labels"]).astype(jnp.float32).mean()
    return loss, acc


def probe_loss(p, batch, cfg: ModelConfig):
    """Sequence-classification probe (GLUE analog): mean-pool + linear head."""
    h = encode_text(p, batch["tokens"], cfg).mean(axis=1)
    logits = h @ p["head_w"].T + p["head_b"]
    loss = _masked_xent(logits, batch["labels"])
    acc = (logits.argmax(-1) == batch["labels"]).astype(jnp.float32).mean()
    return loss, acc


def span_loss(p, batch, cfg: ModelConfig):
    """Span-extraction probe (SQuAD analog): per-token start/end logits."""
    h = encode_text(p, batch["tokens"], cfg)
    logits = h @ p["span_w"].T + p["span_b"]  # (B, S, 2)
    ls, le = logits[..., 0], logits[..., 1]
    loss = _masked_xent(ls, batch["starts"]) + _masked_xent(le, batch["ends"])
    em = ((ls.argmax(-1) == batch["starts"]) & (le.argmax(-1) == batch["ends"]))
    return loss * 0.5, em.astype(jnp.float32).mean()


def kd_loss(p_small, p_large, batch, cfg_s: ModelConfig, cfg_l: ModelConfig, alpha=0.5):
    """Knowledge-inheritance (KI, Qin et al. 2021) objective: task CE mixed
    with KL to the small teacher's distribution. Works for text (token-level)
    and vision (class-level) families."""
    if cfg_s.family in ("vit", "cait"):
        t_logits = encode_vision(p_small, batch["images"], cfg_s) @ p_small["head_w"].T + p_small["head_b"]
        s_logits = encode_vision(p_large, batch["images"], cfg_l) @ p_large["head_w"].T + p_large["head_b"]
    else:
        h_t = encode_text(p_small, batch["tokens"], cfg_s)
        t_logits = h_t @ p_small["emb_tok"].T + p_small["mlm_bias"]
        h_s = encode_text(p_large, batch["tokens"], cfg_l)
        s_logits = h_s @ p_large["emb_tok"].T + p_large["mlm_bias"]
    ce = _masked_xent(s_logits, batch["labels"])
    t_prob = jax.nn.softmax(jax.lax.stop_gradient(t_logits), axis=-1)
    kl = (t_prob * (jnp.log(t_prob + 1e-9) - jax.nn.log_softmax(s_logits))).sum(-1)
    mask = (batch["labels"] >= 0).astype(jnp.float32)
    kl = (kl * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return alpha * ce + (1 - alpha) * kl
