"""L1 Pallas kernel: flash-attention-style fused attention (forward).

The training hot-spot of every transformer in the repo. Online-softmax
schedule a la FlashAttention, re-thought for TPU (DESIGN.md
"Hardware-Adaptation"): instead of a warp-level WMMA tiling, the grid walks
(batch*heads, q_tiles) and an in-kernel fori_loop streams K/V tiles through
VMEM, carrying the running (max, sum, accumulator) in registers/VMEM. Causal
masking is applied per (q_tile, k_tile) pair with iota comparisons.

Executed under interpret=True (CPU PJRT cannot run Mosaic custom-calls).

The backward pass is delegated to the standard softmax-attention gradient in
plain jnp via jax.custom_vjp: XLA fuses it well, and it keeps the kernel
surface small while the forward (the inference/serving hot path and ~1/3 of
training compute) exercises the Pallas schedule.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import attention_ref

_NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, bq, bk, s_k, causal, scale):
    """One (bh, q_tile) grid step: stream K/V tiles with online softmax."""
    qi = pl.program_id(1)
    q = q_ref[0, :, :].astype(jnp.float32) * scale  # (bq, dh)
    dh = q.shape[-1]

    def body(j, carry):
        m_prev, l_prev, acc = carry
        k_tile = pl.load(k_ref, (0, pl.ds(j * bk, bk), slice(None))).astype(jnp.float32)
        v_tile = pl.load(v_ref, (0, pl.ds(j * bk, bk), slice(None))).astype(jnp.float32)
        s = q @ k_tile.T  # (bq, bk)
        if causal:
            q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(q_pos >= k_pos, s, _NEG_INF)
        m_cur = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_cur = l_prev * alpha + p.sum(axis=-1)
        acc = acc * alpha[:, None] + p @ v_tile
        return m_cur, l_cur, acc

    m0 = jnp.full((bq,), _NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((bq,), dtype=jnp.float32)
    acc0 = jnp.zeros((bq, dh), dtype=jnp.float32)
    n_k = s_k // bk
    if causal:
        # keys strictly after this q-tile's last row never contribute
        n_k_eff = jnp.minimum(n_k, (qi + 1) * bq // bk + jnp.where((qi + 1) * bq % bk != 0, 1, 0))
    else:
        n_k_eff = n_k
    m, l, acc = jax.lax.fori_loop(0, n_k_eff, body, (m0, l0, acc0))
    o_ref[0, :, :] = (acc / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk"))
def _attention_pallas(q, k, v, causal=False, bq=64, bk=64):
    """q, k, v: (BH, S, Dh) -> (BH, S, Dh)."""
    bh, s, dh = q.shape
    while s % bq != 0:
        bq //= 2
    while s % bk != 0:
        bk //= 2
    grid = (bh, s // bq)
    scale = 1.0 / (dh ** 0.5)
    kernel = functools.partial(
        _attn_kernel, bq=bq, bk=bk, s_k=s, causal=causal, scale=scale
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, s, dh), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, s, dh), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dh), q.dtype),
        interpret=True,
    )(q, k, v)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def attention(q, k, v, causal=False):
    """Fused attention over (BH, S, Dh) tensors. Differentiable."""
    return _attention_pallas(q, k, v, causal=causal)


def _fwd(q, k, v, causal):
    return _attention_pallas(q, k, v, causal=causal), (q, k, v)


def _bwd(causal, res, do):
    q, k, v = res
    # Standard softmax-attention backward in f32 jnp; recomputes probs
    # (flash-style rematerialization: nothing quadratic was saved in fwd).
    dh = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) / jnp.sqrt(jnp.float32(dh))
    if causal:
        mask = jnp.tril(jnp.ones((q.shape[1], k.shape[1]), dtype=bool))
        s = jnp.where(mask, s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    do32 = do.astype(jnp.float32)
    v32 = v.astype(jnp.float32)
    dv = jnp.einsum("bqk,bqd->bkd", p, do32)
    dp = jnp.einsum("bqd,bkd->bqk", do32, v32)
    ds = p * (dp - (dp * p).sum(axis=-1, keepdims=True))
    ds = ds / jnp.sqrt(jnp.float32(dh))
    dq = jnp.einsum("bqk,bkd->bqd", ds, k.astype(jnp.float32)).astype(q.dtype)
    dk = jnp.einsum("bqk,bqd->bkd", ds, q.astype(jnp.float32)).astype(k.dtype)
    return dq, dk, dv.astype(v.dtype)


attention.defvjp(_fwd, _bwd)


def attention_oracle(q, k, v, causal=False):
    """Re-export of the pure-jnp oracle for tests."""
    return attention_ref(q, k, v, causal=causal)
