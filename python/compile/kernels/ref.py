"""Pure-jnp correctness oracles for the Pallas kernels.

Every Pallas kernel in this package has a reference implementation here,
written with plain jax.numpy so that pytest can assert allclose between the
kernel (interpret=True) and the oracle across shape/dtype sweeps. These are
also the semantic definitions used by the L2 model docs.
"""

import jax.numpy as jnp


def ligo_expand_ref(b, w, a):
    """LiGO width expansion: Omega = B @ W @ A^T.

    This is Eq. 6/7 of the paper: a layer's weight matrix ``w`` (out_s, in_s)
    grows to (out_l, in_l) by taking learned linear combinations of its rows
    (via ``b``: (out_l, out_s)) and columns (via ``a``: (in_l, in_s)).

    Shapes are fully general: b (m, k), w (k, n), a (p, n) -> (m, p).
    """
    return b @ w @ a.T


def attention_ref(q, k, v, causal=False):
    """Scaled dot-product attention oracle.

    q, k, v: (..., S, Dh). Softmax over the key axis in f32; optional causal
    mask. Matches the Pallas flash-attention kernel's semantics exactly.
    """
    dh = q.shape[-1]
    scores = jnp.einsum("...qd,...kd->...qk", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(dh))
    if causal:
        s_q, s_k = scores.shape[-2], scores.shape[-1]
        mask = jnp.tril(jnp.ones((s_q, s_k), dtype=bool))
        scores = jnp.where(mask, scores, jnp.float32(-1e30))
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("...qk,...kd->...qd", probs, v.astype(jnp.float32)).astype(q.dtype)


def layernorm_ref(x, g, b, eps=1e-5):
    """LayerNorm oracle over the last axis."""
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b
