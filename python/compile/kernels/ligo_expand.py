"""L1 Pallas kernel: fused LiGO width expansion Omega = B @ W @ A^T.

This is the compute hot-spot of the LiGO growth operator (paper Eq. 6/7):
during each of the M-learning steps, EVERY weight matrix of the small model
is re-materialized into the large model's shape via the two-sided product
B_l W_l A_l^T before the forward pass, so this triple product runs
(#layers x #modules) times per LiGO gradient step.

TPU-oriented schedule (executed here under interpret=True; see
DESIGN.md "Hardware-Adaptation"):
  - grid = (m_tiles, p_tiles, k_tiles); the k axis is the contraction over
    the small model's output dim and is sequential ("arbitrary" semantics),
    accumulating into the VMEM-resident output tile.
  - per grid step the kernel holds a (bm, bk) tile of B, a (bk, n) strip of
    W, a (bp, n) strip of A and the (bm, bp) output tile in VMEM; the inner
    compute is two MXU-shaped matmuls: T = W_strip @ A_strip^T (bk x bp)
    followed by B_tile @ T (bm x bp).
  - the W @ A^T partial is NOT materialized in HBM -- it only ever exists as
    a (bk, bp) VMEM tile, which is the point of fusing the triple product.

The public entrypoint `ligo_expand` wraps the kernel in jax.custom_vjp so the
LiGO M-parameters can be trained by jax.grad: all three cotangents are
themselves triple products with the same structure, so the backward pass
reuses this very kernel:
    dB = dO @ A @ W^T = expand(dO, A,  W)
    dW = B^T @ dO @ A = expand(B^T, dO, A^T)
    dA = dO^T @ B @ W = expand(dO^T, B, W^T)
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(dim, target):
    """Largest divisor of `dim` that is <= target (keeps tiles aligned)."""
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


def _expand_kernel(b_ref, w_ref, a_ref, o_ref):
    """One (m, p, k) grid step: o[m_tile, p_tile] += B_tile @ (W_strip @ A_strip^T)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # (bk, n) @ (n, bp) -> (bk, bp): the fused W A^T partial, VMEM-only.
    t = jnp.dot(w_ref[...], a_ref[...].T, preferred_element_type=jnp.float32)
    # (bm, bk) @ (bk, bp) -> (bm, bp) accumulation into the output tile.
    o_ref[...] += jnp.dot(b_ref[...], t, preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bp", "bk"))
def _expand_pallas(b, w, a, bm=512, bp=512, bk=512):
    # Default 512-blocks: ~7 MiB VMEM for the paper-scale FFN growth (fits
    # the 16 MiB budget) and, crucially, a small grid under interpret=True,
    # whose sequential while-loop emulation dominates CPU wallclock for the
    # ~100M e2e pair (2304 grid steps at 128-blocks -> 48 at 512-blocks).
    # On real TPU, 128-blocks (see compile.perf) trade VMEM for pipelining.
    """Raw pallas_call wrapper: b (m, k), w (k, n), a (p, n) -> (m, p)."""
    m, k = b.shape
    k2, n = w.shape
    p, n2 = a.shape
    assert k == k2 and n == n2, f"shape mismatch: {b.shape} {w.shape} {a.shape}"
    bm = _pick_block(m, bm)
    bp = _pick_block(p, bp)
    bk = _pick_block(k, bk)
    grid = (m // bm, p // bp, k // bk)
    return pl.pallas_call(
        _expand_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),   # B tile
            pl.BlockSpec((bk, n), lambda i, j, kk: (kk, 0)),    # W strip
            pl.BlockSpec((bp, n), lambda i, j, kk: (j, 0)),     # A strip
        ],
        out_specs=pl.BlockSpec((bm, bp), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, p), b.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(b, w, a)


@jax.custom_vjp
def ligo_expand(b, w, a):
    """Omega = B @ W @ A^T via the fused Pallas kernel. Differentiable."""
    return _expand_pallas(b, w, a)


def _fwd(b, w, a):
    return _expand_pallas(b, w, a), (b, w, a)


def _bwd(res, do):
    b, w, a = res
    db = _expand_pallas(do, a, w)          # dO @ A @ W^T
    dw = _expand_pallas(b.T, do, a.T)      # B^T @ dO @ A
    da = _expand_pallas(do.T, b, w.T)      # dO^T @ B @ W
    return db, dw, da


ligo_expand.defvjp(_fwd, _bwd)


def ligo_expand_batched(b, w, a):
    """vmap over a stack of layers: w (L, k, n); b/a either (L, ., .) or shared (2D)."""
    in_axes = (0 if b.ndim == 3 else None, 0, 0 if a.ndim == 3 else None)
    return jax.vmap(ligo_expand, in_axes=in_axes)(b, w, a)
