"""AOT compile path: lower every artifact to HLO *text* + a JSON manifest.

HLO text (NOT HloModuleProto.serialize()) is the interchange format: jax>=0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (what the
published `xla` 0.1.6 rust crate links) rejects; the text parser reassigns
ids and round-trips cleanly. See /opt/xla-example/README.md.

Per artifact we write:
  artifacts/{name}.hlo.txt        — the XLA computation
  artifacts/{name}.manifest.json  — flattened input/output (name, shape, dtype)
plus once:
  artifacts/configs.json          — the model/pair registry (Rust presets)
  artifacts/goldens.json          — deterministic input/output probes for
                                    cross-language integration tests

Incremental: each manifest records a hash of python/compile/**; unchanged
artifacts are skipped. `--only REGEX` restricts the set; `--force` rebuilds.
"""

import argparse
import hashlib
import json
import os
import re
import sys
import time

import numpy as np
import jax
from jax._src.lib import xla_client as xc

from . import model as M
from . import configs as C
from .detinit import det_fill


def _src_hash() -> str:
    h = hashlib.sha256()
    root = os.path.dirname(os.path.abspath(__file__))
    for dirpath, _dirs, files in sorted(os.walk(root)):
        if "__pycache__" in dirpath:
            continue
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(dirpath, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()[:16]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _flat_entries(tree, prefixes):
    """Flatten a tuple of dicts/leaves exactly the way jax.jit does (dicts in
    sorted-key order), producing [(name, shape, dtype), ...]."""
    out = []
    for prefix, sub in zip(prefixes, tree):
        if isinstance(sub, dict):
            for k in sorted(sub.keys()):
                v = sub[k]
                out.append({"name": f"{prefix}/{k}",
                            "shape": list(v.shape),
                            "dtype": np.dtype(v.dtype).name})
        else:
            out.append({"name": prefix, "shape": list(sub.shape),
                        "dtype": np.dtype(sub.dtype).name})
    return out


_ARG_PREFIXES = {
    "fwd": ("params", "batch"),
    "grad": ("params", "batch"),
    "grad_gated": ("params", "batch"),
    "kd_grad": ("params", "teacher", "batch"),
    "ligo_grad": ("ligo", "small", "batch"),
    "ligo_apply": ("ligo", "small"),
    "span_fwd": ("params", "batch"),
    "span_grad": ("params", "batch"),
    "adapter_fwd": ("trainable", "frozen", "batch"),
    "adapter_grad": ("trainable", "frozen", "batch"),
}

_OUT_PREFIXES = {
    "fwd": ("loss", "metric"),
    "grad": ("loss", "metric", "grads"),
    "grad_gated": ("loss", "grads"),
    "kd_grad": ("loss", "grads"),
    "ligo_grad": ("loss", "grads"),
    "ligo_apply": ("out",),
    "span_fwd": ("loss", "metric"),
    "span_grad": ("loss", "metric", "grads"),
    "adapter_fwd": ("loss", "metric"),
    "adapter_grad": ("loss", "metric", "grads"),
}


def _kind(name: str) -> str:
    for k in sorted(_ARG_PREFIXES, key=len, reverse=True):
        if name.startswith(k + "_"):
            return k
    raise ValueError(name)


def lower_artifact(name, out_dir, src_hash, force=False):
    hlo_path = os.path.join(out_dir, f"{name}.hlo.txt")
    man_path = os.path.join(out_dir, f"{name}.manifest.json")
    if not force and os.path.exists(hlo_path) and os.path.exists(man_path):
        try:
            with open(man_path) as f:
                if json.load(f).get("src_hash") == src_hash:
                    return "cached"
        except Exception:
            pass
    t0 = time.time()
    fn, specs = M.build(name)
    lowered = jax.jit(fn, keep_unused=True).lower(*specs)
    text = to_hlo_text(lowered)
    kind = _kind(name)
    inputs = _flat_entries(specs, _ARG_PREFIXES[kind])

    out_shape = jax.eval_shape(fn, *specs)
    if not isinstance(out_shape, tuple):
        out_shape = (out_shape,)
    out_prefixes = list(_OUT_PREFIXES[kind])[: len(out_shape)]
    # variable-arity outputs: fwd/grad may or may not carry a metric
    if kind in ("fwd", "grad") and len(out_shape) < len(_OUT_PREFIXES[kind]):
        out_prefixes = (["loss", "grads"] if kind == "grad" else ["loss"])[: len(out_shape)]
    outputs = _flat_entries(out_shape, out_prefixes)

    with open(hlo_path, "w") as f:
        f.write(text)
    with open(man_path, "w") as f:
        json.dump({"name": name, "src_hash": src_hash,
                   "inputs": inputs, "outputs": outputs}, f, indent=1)
    return f"built in {time.time() - t0:.1f}s ({len(text) // 1024} KiB)"


# ----------------------------------------------------------------------------
# Goldens: run tiny graphs with deterministic fills, record probes so the Rust
# integration tests can verify the runtime end-to-end with exact expectations.
# ----------------------------------------------------------------------------

def _det_batch(cfg, seed=7):
    bs = M.batch_specs(cfg)
    out = {}
    for k in sorted(bs):
        s = bs[k]
        n = int(np.prod(s.shape)) if s.shape else 1
        idx = np.arange(n, dtype=np.int64)
        if np.dtype(s.dtype) == np.int32:
            hi = cfg.vocab if k == "tokens" else max(cfg.n_classes, 2)
            if k in ("starts", "ends"):
                hi = cfg.seq
            vals = ((idx * 2654435761 + seed) % hi).astype(np.int32)
            if k == "labels" and cfg.family in ("bert", "gpt") and not cfg.n_classes:
                vals = np.where(idx % 7 == 0, vals % cfg.vocab, -1).astype(np.int32)
            out[k] = vals.reshape(s.shape)
        else:
            out[k] = (((idx * 1103515245 + seed) % 1000) / 1000.0 - 0.5).astype(
                np.float32).reshape(s.shape)
    return out


def emit_goldens(out_dir):
    """Golden fwd losses for the small graphs, with detinit params."""
    goldens = {}
    for name in ("bert_small", "gpt_base", "vit_s"):
        cfg = C.REGISTRY[name]
        shapes = M.param_shapes(cfg)
        params = {k: det_fill(k, v) for k, v in shapes.items()}
        batch = _det_batch(cfg)
        fn, _ = M.build(f"fwd_{name}")
        res = fn(params, batch)
        goldens[f"fwd_{name}"] = {
            "loss": float(res[0]),
            "batch_seed": 7,
            "probe_params": {
                k: [float(x) for x in np.asarray(params[k]).reshape(-1)[:4]]
                for k in list(sorted(shapes))[:3]
            },
        }
    with open(os.path.join(out_dir, "goldens.json"), "w") as f:
        json.dump(goldens, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None, help="regex filter on artifact names")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    names = sorted(M.artifact_registry().keys())
    if args.list:
        print("\n".join(names))
        return
    if args.only:
        names = [n for n in names if re.search(args.only, n)]
    os.makedirs(args.out, exist_ok=True)
    src = _src_hash()

    with open(os.path.join(args.out, "configs.json"), "w") as f:
        json.dump(C.to_json(), f, indent=1)

    t0 = time.time()
    for i, n in enumerate(names):
        status = lower_artifact(n, args.out, src, force=args.force)
        print(f"[{i + 1}/{len(names)}] {n}: {status}", flush=True)
    emit_goldens(args.out)
    print(f"configs.json + goldens.json written; total {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
