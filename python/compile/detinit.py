"""Deterministic, language-portable parameter initialization.

The Rust coordinator initializes model parameters natively (python never runs
at runtime), so both sides implement the SAME integer LCG scheme; goldens in
`python/tests` and `rust/tests` assert bit-identical fills. The scheme:

  seed  = low32(FNV-1a(name) ^ global_seed)
  z_i   = mix32(seed + i * 0x9E3779B9)                 (counter-based, splitmix-style)
  u_i   = z_i / 2^32                                   (in [0, 1))
  value = (u_i - 0.5) * 2 * scale                      (uniform, exact in f32)

mix32(z): z ^= z>>16; z *= 0x45D9F3B; z ^= z>>16; z *= 0x45D9F3B; z ^= z>>16
(all mod 2^32). Counter-based => vectorizable in numpy and embarrassingly
portable to Rust.

Per-tensor scale rule (by name suffix):
  *_g / *ln_g        -> constant 1.0
  *ls1 / *ls2        -> constant 0.1
  *_b / mlm_bias     -> constant 0.0
  emb_* / head_w / span_w -> scale 0.02
  matrices (2D)      -> sqrt(6/(fan_in+fan_out))  (uniform Glorot)
"""

import numpy as np

FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x100000001B3
GOLDEN = 0x9E3779B9
MIX = 0x45D9F3B


def fnv1a(name: str) -> int:
    h = FNV_OFFSET
    for ch in name.encode("utf-8"):
        h = ((h ^ ch) * FNV_PRIME) & 0xFFFFFFFFFFFFFFFF
    return h


def tensor_scale(name: str, shape) -> float:
    """The per-tensor init scale (mirrors rust/src/tensor/init.rs)."""
    if name.endswith("_g"):
        return -1.0  # sentinel: constant one
    if name.endswith("ls1") or name.endswith("ls2"):
        return -2.0  # sentinel: constant 0.1
    if name.endswith("_b") or name == "mlm_bias":
        return 0.0
    if name.startswith("emb_") or name in ("head_w", "span_w"):
        return 0.02
    if len(shape) == 2:
        fan_out, fan_in = shape
        return float(np.sqrt(6.0 / (fan_in + fan_out)))
    return 0.02


def det_fill(name: str, shape, global_seed: int = 0) -> np.ndarray:
    """Deterministic fill identical to the Rust implementation."""
    scale = tensor_scale(name, shape)
    n = int(np.prod(shape)) if len(shape) else 1
    if scale == -1.0:
        return np.ones(shape, np.float32)
    if scale == -2.0:
        return np.full(shape, 0.1, np.float32)
    if scale == 0.0:
        return np.zeros(shape, np.float32)
    seed = np.uint32((fnv1a(name) ^ (global_seed & 0xFFFFFFFFFFFFFFFF)) & 0xFFFFFFFF)
    with np.errstate(over="ignore"):
        z = seed + np.arange(n, dtype=np.uint32) * np.uint32(GOLDEN)
        z ^= z >> np.uint32(16)
        z *= np.uint32(MIX)
        z ^= z >> np.uint32(16)
        z *= np.uint32(MIX)
        z ^= z >> np.uint32(16)
    u = z.astype(np.float64) / 4294967296.0
    return (((u - 0.5) * 2.0 * scale).astype(np.float32)).reshape(shape)


def det_params(shapes: dict, global_seed: int = 0) -> dict:
    """Fill a whole {name: shape} spec."""
    return {k: det_fill(k, v, global_seed) for k, v in sorted(shapes.items())}
