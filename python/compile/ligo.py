"""L2: the LiGO operator (paper Sections 3.2-3.3, Algorithm 1) in JAX.

The growth map  vec(Theta_new) = (w (x) I) . blockdiag(A_l (x) B_l) vec(Theta)
is implemented exactly as Algorithm 1: a width-expansion pass that grows every
small-model tensor via the fused Pallas kernel `ligo_expand` (B @ W @ A^T),
followed by a depth-expansion pass that forms each large layer as a learned
linear blend of the width-grown small layers.

Weight tying (Appendix B.1), which makes M learnable from ~100 steps:
  * A^k = B_emb^T for k in {Q, K, V, fc1}   (residual-stream input alignment)
  * A^O = B_V^T,  A^fc2 = B_fc1^T           (inner-dim alignment)
  * B^O = B^fc2 = B_emb                     (residual-stream output alignment)
  * biases / LayerNorms grow with their module's out-expansion matrix
  * output head: A^out = B_emb^T, no out-expansion

Learned LiGO parameters (flat dict):
  B_emb (D2, D1); B_q, B_k, B_v (D2, D1); B_fc1 (F2, F1)  [shared across layers]
  w_q, w_k, w_v, w_o, w_ln1, w_fc1, w_fc2, w_ln2 (L2, L1) [per-module depth blends]
  (vision: same, plus nothing extra — patch/cls/pos/head all ride on B_emb)

Special cases (Prop. 1): with B_* set to the Net2Net selection pattern and
w set to the stacking pattern, M reproduces StackBERT / Interpolation /
Net2Net exactly — that is also how we *initialize* M before the 100 SGD steps.
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels.ligo_expand import ligo_expand, ligo_expand_batched

DEPTH_MODULES = ("q", "k", "v", "o", "ln1", "fc1", "fc2", "ln2")
CAIT_EXTRA = ("ls1", "ls2")


def expansion_ratio(small: ModelConfig, large: ModelConfig):
    return large.layers // small.layers if small.layers else 1


# ----------------------------------------------------------------------------
# Initialization of M (stacking + neuron-duplication pattern, Prop. 1)
# ----------------------------------------------------------------------------

def _dup_expand_matrix(key, d2, d1, noise=0.01):
    """(d2, d1) matrix whose row i selects small-row (i mod d1): the Net2Net
    neuron-duplication pattern, plus symmetry-breaking noise."""
    eye = jnp.eye(d1, dtype=jnp.float32)
    m = jnp.tile(eye, ((d2 + d1 - 1) // d1, 1))[:d2]
    return m + noise * jax.random.normal(key, (d2, d1), jnp.float32)


def _stack_matrix(key, l2, l1, noise=0.01):
    """(l2, l1) depth-blend init: StackBERT pattern w[i, i mod l1] = 1."""
    rows = jnp.eye(l1, dtype=jnp.float32)
    m = jnp.tile(rows, ((l2 + l1 - 1) // l1, 1))[:l2]
    return m + noise * jax.random.normal(key, (l2, l1), jnp.float32)


def ligo_init(key, small: ModelConfig, large: ModelConfig) -> dict:
    """Initialize LiGO parameters M. Width params are omitted when D1 == D2
    (depth-only growth); depth params are omitted when L1 == L2 (width-only),
    matching the paper's ablations (Fig. 6)."""
    keys = jax.random.split(key, 16)
    p = {}
    if small.dim != large.dim:
        p["B_emb"] = _dup_expand_matrix(keys[0], large.dim, small.dim)
        p["B_q"] = _dup_expand_matrix(keys[1], large.dim, small.dim)
        p["B_k"] = _dup_expand_matrix(keys[2], large.dim, small.dim)
        p["B_v"] = _dup_expand_matrix(keys[3], large.dim, small.dim)
        p["B_fc1"] = _dup_expand_matrix(keys[4], large.ffn, small.ffn)
    if small.layers != large.layers:
        for i, m in enumerate(DEPTH_MODULES):
            p[f"w_{m}"] = _stack_matrix(keys[5 + i], large.layers, small.layers)
        if small.family == "cait":
            for i, m in enumerate(CAIT_EXTRA):
                p[f"w_{m}"] = _stack_matrix(keys[13 + i], large.layers, small.layers)
    return p


# ----------------------------------------------------------------------------
# Applying M: width pass (Pallas kernel) + depth pass (learned blends)
# ----------------------------------------------------------------------------

def _get_b(lp, name, d2, d1):
    """Width matrix or identity fallback (depth-only growth)."""
    if name in lp:
        return lp[name]
    assert d2 == d1, f"missing {name} but dims differ: {d2} vs {d1}"
    return jnp.eye(d1, dtype=jnp.float32)


def _stack(small_p, small: ModelConfig, suffix, prefix="L"):
    return jnp.stack([small_p[f"{prefix}{l:02d}_{suffix}"] for l in range(small.layers)])


def _depth_blend(lp, name, stack, large_layers):
    """stack: (L1, ...) width-grown module tensors -> (L2, ...) blended."""
    if f"w_{name}" in lp:
        w = lp[f"w_{name}"]
        return jnp.einsum("ij,j...->i...", w, stack)
    assert stack.shape[0] == large_layers
    return stack


def ligo_apply(lp: dict, small_p: dict, small: ModelConfig, large: ModelConfig,
               prefix="L", n_layers_small=None, n_layers_large=None) -> dict:
    """Materialize the large model's parameters: Theta_new = M(Theta).

    Returns a flat dict with the large config's parameter names. Differentiable
    w.r.t. `lp` (and `small_p`), so jax.grad can train M on the task loss.
    """
    d1, d2, f1, f2 = small.dim, large.dim, small.ffn, large.ffn
    l1 = n_layers_small or small.layers
    l2 = n_layers_large or large.layers
    b_emb = _get_b(lp, "B_emb", d2, d1)
    b_q = _get_b(lp, "B_q", d2, d1)
    b_k = _get_b(lp, "B_k", d2, d1)
    b_v = _get_b(lp, "B_v", d2, d1)
    b_fc1 = _get_b(lp, "B_fc1", f2, f1)

    out = {}
    # ---- width pass: every per-layer matrix through the fused kernel ----
    # (out_exp, W_stack, in_exp): Omega_l = B W_l A^T, A tied per App. B.1
    wides = {
        "q_w": ligo_expand_batched(b_q, _stack(small_p, small, "q_w", prefix), b_emb),
        "k_w": ligo_expand_batched(b_k, _stack(small_p, small, "k_w", prefix), b_emb),
        "v_w": ligo_expand_batched(b_v, _stack(small_p, small, "v_w", prefix), b_emb),
        "o_w": ligo_expand_batched(b_emb, _stack(small_p, small, "o_w", prefix), b_v),
        "fc1_w": ligo_expand_batched(b_fc1, _stack(small_p, small, "fc1_w", prefix), b_emb),
        "fc2_w": ligo_expand_batched(b_emb, _stack(small_p, small, "fc2_w", prefix), b_fc1),
        # biases / LN vectors: one-sided products with the out-expansion
        "q_b": _stack(small_p, small, "q_b", prefix) @ b_q.T,
        "k_b": _stack(small_p, small, "k_b", prefix) @ b_k.T,
        "v_b": _stack(small_p, small, "v_b", prefix) @ b_v.T,
        "o_b": _stack(small_p, small, "o_b", prefix) @ b_emb.T,
        "fc1_b": _stack(small_p, small, "fc1_b", prefix) @ b_fc1.T,
        "fc2_b": _stack(small_p, small, "fc2_b", prefix) @ b_emb.T,
        "ln1_g": _stack(small_p, small, "ln1_g", prefix) @ b_emb.T,
        "ln1_b": _stack(small_p, small, "ln1_b", prefix) @ b_emb.T,
        "ln2_g": _stack(small_p, small, "ln2_g", prefix) @ b_emb.T,
        "ln2_b": _stack(small_p, small, "ln2_b", prefix) @ b_emb.T,
    }
    if small.family == "cait" and prefix == "L":
        wides["ls1"] = _stack(small_p, small, "ls1", prefix) @ b_emb.T
        wides["ls2"] = _stack(small_p, small, "ls2", prefix) @ b_emb.T

    # ---- depth pass: learned per-module blends ----
    mod_to_w = {"q_w": "q", "q_b": "q", "k_w": "k", "k_b": "k", "v_w": "v",
                "v_b": "v", "o_w": "o", "o_b": "o", "fc1_w": "fc1",
                "fc1_b": "fc1", "fc2_w": "fc2", "fc2_b": "fc2",
                "ln1_g": "ln1", "ln1_b": "ln1", "ln2_g": "ln2", "ln2_b": "ln2",
                "ls1": "ls1", "ls2": "ls2"}
    for suffix, stackv in wides.items():
        blended = _depth_blend(lp, mod_to_w[suffix], stackv, l2)
        for l in range(l2):
            out[f"{prefix}{l:02d}_{suffix}"] = blended[l]

    # ---- non-layer tensors ----
    if small.family in ("bert", "gpt"):
        out["emb_tok"] = small_p["emb_tok"] @ b_emb.T
        out["emb_pos"] = small_p["emb_pos"] @ b_emb.T
        out["mlm_bias"] = small_p["mlm_bias"]
    else:
        out["emb_patch_w"] = b_emb @ small_p["emb_patch_w"]
        out["emb_patch_b"] = b_emb @ small_p["emb_patch_b"]
        out["emb_cls"] = b_emb @ small_p["emb_cls"]
        out["emb_pos"] = small_p["emb_pos"] @ b_emb.T
        out["head_w"] = small_p["head_w"] @ b_emb.T
        out["head_b"] = small_p["head_b"]
    out["final_ln_g"] = small_p["final_ln_g"] @ b_emb.T
    out["final_ln_b"] = small_p["final_ln_b"] @ b_emb.T
    if small.n_classes and small.family == "bert" and "head_w" in small_p:
        out["head_w"] = small_p["head_w"] @ b_emb.T
        out["head_b"] = small_p["head_b"]

    # CaiT class-attention stage: widths grow, depth is fixed (Lc1 == Lc2)
    if small.family == "cait":
        for l in range(small.cls_layers):
            pre = f"C{l:02d}_"
            out[f"{pre}q_w"] = ligo_expand(b_q, small_p[f"{pre}q_w"], b_emb)
            out[f"{pre}k_w"] = ligo_expand(b_k, small_p[f"{pre}k_w"], b_emb)
            out[f"{pre}v_w"] = ligo_expand(b_v, small_p[f"{pre}v_w"], b_emb)
            out[f"{pre}o_w"] = ligo_expand(b_emb, small_p[f"{pre}o_w"], b_v)
            out[f"{pre}fc1_w"] = ligo_expand(b_fc1, small_p[f"{pre}fc1_w"], b_emb)
            out[f"{pre}fc2_w"] = ligo_expand(b_emb, small_p[f"{pre}fc2_w"], b_fc1)
            out[f"{pre}q_b"] = b_q @ small_p[f"{pre}q_b"]
            out[f"{pre}k_b"] = b_k @ small_p[f"{pre}k_b"]
            out[f"{pre}v_b"] = b_v @ small_p[f"{pre}v_b"]
            out[f"{pre}o_b"] = b_emb @ small_p[f"{pre}o_b"]
            out[f"{pre}fc1_b"] = b_fc1 @ small_p[f"{pre}fc1_b"]
            out[f"{pre}fc2_b"] = b_emb @ small_p[f"{pre}fc2_b"]
            for ln in ("ln1_g", "ln1_b", "ln2_g", "ln2_b"):
                out[f"{pre}{ln}"] = b_emb @ small_p[f"{pre}{ln}"]
    return out
